//! Adafactor (Shazeer & Stern, 2018) — factored second-moment baseline and
//! the preconditioner inside AdaMeM (paper §B.1).
//!
//! For a matrix parameter the second moment is stored as a rank-1 factor
//! (row accumulator R ∈ ℝ^m, column accumulator C ∈ ℝ^n), costing m+n
//! floats instead of m·n. Vector parameters fall back to a full
//! accumulator.

use super::{Layout, Optimizer, Role};

#[derive(Clone, Copy, Debug)]
pub struct AdafactorCfg {
    pub beta2: f32,
    pub eps: f32,
}

impl Default for AdafactorCfg {
    fn default() -> Self {
        AdafactorCfg { beta2: 0.999, eps: 1e-30 }
    }
}

/// Factored (or full, for vectors) second-moment state for one parameter.
#[derive(Clone, Debug)]
pub enum FactorState {
    Factored { r: Vec<f32>, c: Vec<f32> },
    Full { v: Vec<f32> },
}

impl FactorState {
    pub fn new(rows: usize, cols: usize) -> Self {
        if rows > 1 && cols > 1 {
            FactorState::Factored { r: vec![0.0; rows], c: vec![0.0; cols] }
        } else {
            FactorState::Full { v: vec![0.0; rows * cols] }
        }
    }

    pub fn floats(&self) -> usize {
        match self {
            FactorState::Factored { r, c } => r.len() + c.len(),
            FactorState::Full { v } => v.len(),
        }
    }

    /// Advance the accumulator on `g` (viewed as rows×cols) and write the
    /// preconditioned direction g/sqrt(v̂) into `out`.
    pub fn precondition(
        &mut self,
        g: &[f32],
        rows: usize,
        cols: usize,
        cfg: &AdafactorCfg,
        out: &mut [f32],
    ) {
        match self {
            FactorState::Factored { r, c } => {
                debug_assert_eq!(r.len(), rows);
                debug_assert_eq!(c.len(), cols);
                // Row/col means of g^2 + eps.
                for i in 0..rows {
                    let mut acc = 0.0f32;
                    for j in 0..cols {
                        let x = g[i * cols + j];
                        acc += x * x + cfg.eps;
                    }
                    r[i] = cfg.beta2 * r[i] + (1.0 - cfg.beta2) * (acc / cols as f32);
                }
                for j in 0..cols {
                    let mut acc = 0.0f32;
                    for i in 0..rows {
                        let x = g[i * cols + j];
                        acc += x * x + cfg.eps;
                    }
                    c[j] = cfg.beta2 * c[j] + (1.0 - cfg.beta2) * (acc / rows as f32);
                }
                let r_mean = r.iter().sum::<f32>() / rows as f32;
                for i in 0..rows {
                    for j in 0..cols {
                        let vhat = (r[i] * c[j] / r_mean.max(cfg.eps)).max(cfg.eps);
                        out[i * cols + j] = g[i * cols + j] / vhat.sqrt();
                    }
                }
            }
            FactorState::Full { v } => {
                for i in 0..g.len() {
                    v[i] = cfg.beta2 * v[i] + (1.0 - cfg.beta2) * (g[i] * g[i] + cfg.eps);
                    out[i] = g[i] / v[i].sqrt().max(cfg.eps);
                }
            }
        }
    }
}

/// Full-model Adafactor (no momentum), per-parameter factored states.
pub struct Adafactor {
    cfg: AdafactorCfg,
    layout: Layout,
    states: Vec<FactorState>,
    scratch: Vec<f32>,
}

impl Adafactor {
    pub fn new(layout: Layout, cfg: AdafactorCfg) -> Self {
        let states = layout
            .params
            .iter()
            .map(|p| {
                let (r, c) = p.dims();
                FactorState::new(r, c)
            })
            .collect();
        Adafactor { cfg, layout, states, scratch: Vec::new() }
    }
}

impl Optimizer for Adafactor {
    fn name(&self) -> String {
        "adafactor".into()
    }

    fn step(&mut self, params: &mut [f32], grads: &[f32], lr: f32) {
        for (i, p) in self.layout.params.iter().enumerate() {
            let range = p.offset..p.offset + p.numel();
            let (rows, cols) = p.dims();
            self.scratch.clear();
            self.scratch.resize(p.numel(), 0.0);
            self.states[i].precondition(&grads[range.clone()], rows, cols, &self.cfg,
                                        &mut self.scratch);
            let prm = &mut params[range];
            for lane in 0..prm.len() {
                prm[lane] -= lr * self.scratch[lane];
            }
        }
    }

    fn state_floats(&self) -> usize {
        self.states.iter().map(|s| s.floats()).sum()
    }
}

// Silence unused-import lint for Role (used in docs/tests semantics).
#[allow(unused)]
fn _role_check(r: Role) -> Role {
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn factored_state_is_sublinear() {
        let l = Layout::synthetic(64, 16, 40, 2);
        let opt = Adafactor::new(l.clone(), AdafactorCfg::default());
        // Factored memory must be far below 1x param count for matrices.
        assert!(opt.state_floats() < l.flat_size / 4);
    }

    #[test]
    fn preconditions_toward_signlike_updates() {
        // With a persistent gradient, g/sqrt(EMA g^2) tends to ±1-ish.
        let mut st = FactorState::new(4, 4);
        let cfg = AdafactorCfg { beta2: 0.9, ..Default::default() };
        let g: Vec<f32> = (0..16).map(|i| if i % 2 == 0 { 0.3 } else { -0.01 }).collect();
        let mut out = vec![0.0; 16];
        for _ in 0..200 {
            st.precondition(&g, 4, 4, &cfg, &mut out);
        }
        // factored estimate is rank-1, so magnitudes are approximate;
        // check sign and rough scale only.
        for (o, gi) in out.iter().zip(&g) {
            assert_eq!(o.signum(), gi.signum());
            assert!(o.abs() < 35.0 && o.abs() > 0.02, "o={o}");
        }
    }

    #[test]
    fn converges_on_quadratic() {
        let l = Layout::synthetic(8, 4, 8, 1);
        let mut opt = Adafactor::new(l.clone(), AdafactorCfg::default());
        let mut x = vec![1.0f32; l.padded_size];
        let n0: f32 = x.iter().map(|v| v * v).sum();
        for _ in 0..300 {
            let g = x.clone();
            opt.step(&mut x, &g, 1e-2);
        }
        let n1: f32 = x[..l.flat_size].iter().map(|v| v * v).sum();
        assert!(n1 < 0.5 * n0, "n0={n0} n1={n1}");
    }

    #[test]
    fn vector_params_use_full_state() {
        let st = FactorState::new(1, 16);
        assert_eq!(st.floats(), 16);
        let st2 = FactorState::new(16, 16);
        assert_eq!(st2.floats(), 32);
    }
}
