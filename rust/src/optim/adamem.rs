//! AdaMeM (Vyas et al., 2024) — concurrent method (paper §B.1, Table 20).
//!
//! Splits the gradient into the top-SVD subspace and its residual (like
//! FRUGAL), but fixes the update rules: momentum is kept only in the
//! low-rank subspace and fed through an Adafactor preconditioner, while
//! the residual goes through a ONE-SIDED Adafactor preconditioner with no
//! momentum. Per the paper's framing, this is a special case of FRUGAL
//! with (Adafactor+momentum, one-sided Adafactor) as the rule pair.

use super::adafactor::{AdafactorCfg, FactorState};
use super::adamw::{AdamCfg, AdamState};
use super::projection::{MatrixProjector, Side};
use super::{Layout, Optimizer, Role};
use crate::tensor::Matrix;

#[derive(Clone, Debug)]
pub struct AdaMeMCfg {
    pub rho: f32,
    pub update_freq: u64,
    pub beta1: f32,
    pub factor: AdafactorCfg,
}

impl Default for AdaMeMCfg {
    fn default() -> Self {
        AdaMeMCfg { rho: 0.25, update_freq: 200, beta1: 0.9, factor: AdafactorCfg::default() }
    }
}

struct MemState {
    proj: MatrixProjector,
    /// Momentum in the low-rank subspace.
    m: Vec<f32>,
    /// Adafactor accumulator for the low-rank part.
    low_factor: FactorState,
    /// One-sided accumulator for the residual: one value per residual
    /// row/column (the "one-sided Adafactor" of the paper).
    resid_acc: Vec<f32>,
}

pub struct AdaMeM {
    pub cfg: AdaMeMCfg,
    layout: Layout,
    lin: Vec<Option<MemState>>,
    role_state: Vec<Option<AdamState>>,
    adam_cfg: AdamCfg,
    step_counter: u64,
    scratch: Vec<f32>,
}

impl AdaMeM {
    pub fn new(layout: Layout, cfg: AdaMeMCfg) -> Self {
        let n = layout.params.len();
        let mut role_state: Vec<Option<AdamState>> = (0..n).map(|_| None).collect();
        for (i, p) in layout.params.iter().enumerate() {
            if p.role != Role::Linear {
                role_state[i] = Some(AdamState::new(p.numel()));
            }
        }
        AdaMeM {
            cfg,
            layout,
            lin: (0..n).map(|_| None).collect(),
            role_state,
            adam_cfg: AdamCfg::default(),
            step_counter: 0,
            scratch: Vec::new(),
        }
    }
}

impl Optimizer for AdaMeM {
    fn name(&self) -> String {
        format!("adamem(rho={})", self.cfg.rho)
    }

    fn step(&mut self, params: &mut [f32], grads: &[f32], lr: f32) {
        let refresh = self.step_counter % self.cfg.update_freq == 0;
        self.step_counter += 1;
        for i in 0..self.layout.params.len() {
            let p = self.layout.params[i].clone();
            let range = p.offset..p.offset + p.numel();
            let g = &grads[range.clone()];
            if p.role != Role::Linear {
                let cfg = self.adam_cfg;
                self.role_state[i].as_mut().unwrap().apply(&mut params[range], g, lr, &cfg);
                continue;
            }
            let (rows, cols) = p.dims();
            let gm = Matrix::from_vec(rows, cols, g.to_vec());
            let r = ((self.cfg.rho * rows.min(cols) as f32).round() as usize).max(1);
            if refresh || self.lin[i].is_none() {
                let proj = MatrixProjector::from_svd(&gm, r);
                let (lr_rows, lr_cols) = match proj.side {
                    Side::Left => (proj.rank(), cols),
                    Side::Right => (rows, proj.rank()),
                };
                // Residual one-sided accumulator: per the larger dimension.
                let resid_len = rows.max(cols);
                self.lin[i] = Some(MemState {
                    proj,
                    m: vec![0.0; lr_rows * lr_cols],
                    low_factor: FactorState::new(lr_rows, lr_cols),
                    resid_acc: vec![0.0; resid_len],
                });
            }
            let beta1 = self.cfg.beta1;
            let factor_cfg = self.cfg.factor;
            let st = self.lin[i].as_mut().unwrap();
            let low = st.proj.down(&gm);
            // Momentum on the low-rank gradient.
            for (mi, gi) in st.m.iter_mut().zip(&low.data) {
                *mi = beta1 * *mi + (1.0 - beta1) * gi;
            }
            // Adafactor preconditioning of the momentum.
            let (lrows, lcols) = (low.rows, low.cols);
            self.scratch.clear();
            self.scratch.resize(st.m.len(), 0.0);
            let m_snapshot = st.m.clone();
            st.low_factor.precondition(&m_snapshot, lrows, lcols, &factor_cfg,
                                       &mut self.scratch);
            let low_upd = Matrix::from_vec(lrows, lcols, self.scratch.clone());
            let full_upd = st.proj.up(&low_upd);

            // Residual through one-sided Adafactor (no momentum): EMA of
            // per-row (or per-col) mean square, preconditioned division.
            let back = st.proj.up(&low);
            let resid = gm.sub(&back);
            let one_sided_rows = rows >= cols;
            if one_sided_rows {
                for ri in 0..rows {
                    let mut acc = 0.0f32;
                    for j in 0..cols {
                        let x = resid[(ri, j)];
                        acc += x * x;
                    }
                    st.resid_acc[ri] = factor_cfg.beta2 * st.resid_acc[ri]
                        + (1.0 - factor_cfg.beta2) * (acc / cols as f32);
                }
            } else {
                for j in 0..cols {
                    let mut acc = 0.0f32;
                    for ri in 0..rows {
                        let x = resid[(ri, j)];
                        acc += x * x;
                    }
                    st.resid_acc[j] = factor_cfg.beta2 * st.resid_acc[j]
                        + (1.0 - factor_cfg.beta2) * (acc / rows as f32);
                }
            }
            let prm = &mut params[range];
            for ri in 0..rows {
                for j in 0..cols {
                    let lane = ri * cols + j;
                    let denom = if one_sided_rows { st.resid_acc[ri] } else { st.resid_acc[j] };
                    let resid_upd = resid[(ri, j)] / denom.sqrt().max(1e-8);
                    prm[lane] -= lr * (full_upd.data[lane] + resid_upd);
                }
            }
        }
    }

    fn state_floats(&self) -> usize {
        let role: usize = self.role_state.iter().flatten().map(|s| s.floats()).sum();
        let lin: usize = self
            .lin
            .iter()
            .flatten()
            .map(|s| s.proj.floats() + s.m.len() + s.low_factor.floats() + s.resid_acc.len())
            .sum();
        role + lin
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    
    use crate::util::Prng;

    fn layout() -> Layout {
        Layout::synthetic(32, 8, 20, 2)
    }

    fn grads(l: &Layout, seed: u64) -> Vec<f32> {
        let mut rng = Prng::seed_from_u64(seed);
        let mut g = vec![0.0f32; l.padded_size];
        for v in g[..l.flat_size].iter_mut() {
            *v = crate::tensor::matrix::normal_sample(&mut rng) * 0.1;
        }
        g
    }

    #[test]
    fn full_rank_updates() {
        let l = layout();
        let mut opt = AdaMeM::new(l.clone(), AdaMeMCfg::default());
        let g = grads(&l, 0);
        let mut p = vec![0.0f32; l.padded_size];
        opt.step(&mut p, &g, 1e-3);
        let info = l.linears().next().unwrap();
        let (rows, cols) = info.dims();
        let upd =
            Matrix::from_vec(rows, cols, p[info.offset..info.offset + info.numel()].to_vec());
        let s = crate::linalg::svd(&upd).s;
        let r = ((0.25 * rows.min(cols) as f32).round() as usize).max(1);
        assert!(s[r] > 1e-3 * s[0], "residual missing: {s:?}");
    }

    #[test]
    fn state_is_sublinear_in_linear_params() {
        let l = Layout::synthetic(64, 16, 40, 4);
        let mut opt = AdaMeM::new(l.clone(), AdaMeMCfg { rho: 0.25, ..Default::default() });
        let g = grads(&l, 1);
        let mut p = vec![0.0f32; l.padded_size];
        opt.step(&mut p, &g, 1e-3);
        let role: usize =
            l.params.iter().filter(|p| p.role != Role::Linear).map(|p| p.numel()).sum();
        let lin_state = opt.state_floats() - 2 * role;
        assert!(lin_state < l.linear_numel(), "adamem state not sublinear");
    }

    #[test]
    fn converges_on_quadratic() {
        let l = layout();
        let mut opt = AdaMeM::new(l.clone(), AdaMeMCfg { update_freq: 5, ..Default::default() });
        let mut p = grads(&l, 2);
        let n0: f32 = p.iter().map(|x| x * x).sum();
        for _ in 0..50 {
            let g = p.clone();
            opt.step(&mut p, &g, 1e-3);
        }
        let n1: f32 = p.iter().map(|x| x * x).sum();
        assert!(n1 < n0);
    }
}
