//! AdamW — the memory-hungry upper-bound baseline (paper Table 2 row 1)
//! and the state-full update rule inside FRUGAL/BAdam/GaLore.


use super::Optimizer;
use crate::tensor::bf16_round;

/// Adam hyper-parameters (paper §A.1 defaults).
#[derive(Clone, Copy, Debug)]
pub struct AdamCfg {
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
    /// Store m/v (and round updates) through bf16 — the "pure bf16"
    /// regime of paper Tables 3/9.
    pub bf16_state: bool,
}

impl Default for AdamCfg {
    fn default() -> Self {
        AdamCfg { beta1: 0.9, beta2: 0.999, eps: 1e-8, weight_decay: 0.0, bf16_state: false }
    }
}

impl AdamCfg {
    /// The paper's Table 8 ablation value.
    pub fn beta2_095() -> Self {
        AdamCfg { beta2: 0.95, ..Default::default() }
    }
}

/// Reusable Adam state over an arbitrary number of lanes. Shared by every
/// optimizer that embeds an Adam-style update (FRUGAL, GaLore, BAdam, …).
#[derive(Clone, Debug)]
pub struct AdamState {
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    /// Per-buffer step count for bias correction (resets with the buffer —
    /// the correct behaviour after a subspace change, §D).
    pub t: u64,
}

impl AdamState {
    pub fn new(n: usize) -> Self {
        AdamState { m: vec![0.0; n], v: vec![0.0; n], t: 0 }
    }

    pub fn reset(&mut self) {
        self.m.iter_mut().for_each(|x| *x = 0.0);
        self.v.iter_mut().for_each(|x| *x = 0.0);
        self.t = 0;
    }

    /// Advance state on `grads` and write the (unscaled-by-lr) update into
    /// `out`: out[i] = m̂ / (sqrt(v̂) + eps). Returns nothing; caller applies
    /// `p -= lr * (out + wd * p)`.
    pub fn update_into(&mut self, grads: &[f32], cfg: &AdamCfg, out: &mut [f32]) {
        debug_assert_eq!(grads.len(), self.m.len());
        debug_assert_eq!(out.len(), self.m.len());
        self.t += 1;
        let bc1 = 1.0 - cfg.beta1.powi(self.t as i32);
        let bc2 = 1.0 - cfg.beta2.powi(self.t as i32);
        if cfg.bf16_state {
            // Rare (ablation) path: keep the simple scalar loop.
            for i in 0..grads.len() {
                let g = grads[i];
                let m = bf16_round(cfg.beta1 * self.m[i] + (1.0 - cfg.beta1) * g);
                let v = bf16_round(cfg.beta2 * self.v[i] + (1.0 - cfg.beta2) * g * g);
                self.m[i] = m;
                self.v[i] = v;
                out[i] = (m / bc1) / ((v / bc2).sqrt() + cfg.eps);
            }
            return;
        }
        adam_update_kernel(&mut self.m, &mut self.v, grads, out, cfg, bc1, bc2);
    }

    /// Fused apply: `p -= lr * (adam_update + wd * p)` without a scratch
    /// buffer — the hot path used by the full-rank baseline.
    pub fn apply(&mut self, params: &mut [f32], grads: &[f32], lr: f32, cfg: &AdamCfg) {
        debug_assert_eq!(grads.len(), self.m.len());
        self.t += 1;
        let bc1 = 1.0 - cfg.beta1.powi(self.t as i32);
        let bc2 = 1.0 - cfg.beta2.powi(self.t as i32);
        if cfg.bf16_state {
            for i in 0..grads.len() {
                let g = grads[i];
                let m = bf16_round(cfg.beta1 * self.m[i] + (1.0 - cfg.beta1) * g);
                let v = bf16_round(cfg.beta2 * self.v[i] + (1.0 - cfg.beta2) * g * g);
                self.m[i] = m;
                self.v[i] = v;
                let upd = (m / bc1) / ((v / bc2).sqrt() + cfg.eps) + cfg.weight_decay * params[i];
                params[i] -= lr * upd;
            }
            return;
        }
        adam_apply_kernel(&mut self.m, &mut self.v, params, grads, lr, cfg, bc1, bc2, true);
    }

    /// Fused state-full step WITHOUT weight decay: exactly
    /// [`AdamState::update_into`] followed by `p -= lr * out`, collapsed
    /// into one pass (identical per-lane operations and order ⇒ identical
    /// bits, with no scratch buffer and no second sweep over memory).
    /// This is FRUGAL's state-full hot path — its historical two-pass
    /// route never applied decay, so the fused form must not either.
    pub fn apply_no_decay(&mut self, params: &mut [f32], grads: &[f32], lr: f32, cfg: &AdamCfg) {
        debug_assert_eq!(grads.len(), self.m.len());
        debug_assert_eq!(params.len(), self.m.len());
        self.t += 1;
        let bc1 = 1.0 - cfg.beta1.powi(self.t as i32);
        let bc2 = 1.0 - cfg.beta2.powi(self.t as i32);
        if cfg.bf16_state {
            for i in 0..grads.len() {
                let g = grads[i];
                let m = bf16_round(cfg.beta1 * self.m[i] + (1.0 - cfg.beta1) * g);
                let v = bf16_round(cfg.beta2 * self.v[i] + (1.0 - cfg.beta2) * g * g);
                self.m[i] = m;
                self.v[i] = v;
                params[i] -= lr * ((m / bc1) / ((v / bc2).sqrt() + cfg.eps));
            }
            return;
        }
        adam_apply_kernel(&mut self.m, &mut self.v, params, grads, lr, cfg, bc1, bc2, false);
    }

    pub fn floats(&self) -> usize {
        self.m.len() + self.v.len()
    }
}

/// Lanes per fixed-width chunk in the Adam kernels. The inner loop over
/// a chunk has a compile-time bound and no cross-lane dependence, so
/// LLVM autovectorizes it; per-lane arithmetic and order are exactly the
/// scalar loop's (same inputs ⇒ same bits — the determinism contract the
/// engine's CI gates rely on).
const ADAM_CHUNK: usize = 8;

/// Chunked m/v advance + unscaled update write (the f32-state fast path
/// of [`AdamState::update_into`]).
fn adam_update_kernel(
    m: &mut [f32],
    v: &mut [f32],
    g: &[f32],
    out: &mut [f32],
    cfg: &AdamCfg,
    bc1: f32,
    bc2: f32,
) {
    let (beta1, beta2, eps) = (cfg.beta1, cfg.beta2, cfg.eps);
    let n = g.len();
    let split = n - n % ADAM_CHUNK;
    let (m_main, m_tail) = m.split_at_mut(split);
    let (v_main, v_tail) = v.split_at_mut(split);
    let (o_main, o_tail) = out.split_at_mut(split);
    let (g_main, g_tail) = g.split_at(split);
    for (((mc, vc), oc), gc) in m_main
        .chunks_exact_mut(ADAM_CHUNK)
        .zip(v_main.chunks_exact_mut(ADAM_CHUNK))
        .zip(o_main.chunks_exact_mut(ADAM_CHUNK))
        .zip(g_main.chunks_exact(ADAM_CHUNK))
    {
        for k in 0..ADAM_CHUNK {
            let gk = gc[k];
            let mk = beta1 * mc[k] + (1.0 - beta1) * gk;
            let vk = beta2 * vc[k] + (1.0 - beta2) * gk * gk;
            mc[k] = mk;
            vc[k] = vk;
            oc[k] = (mk / bc1) / ((vk / bc2).sqrt() + eps);
        }
    }
    for k in 0..m_tail.len() {
        let gk = g_tail[k];
        let mk = beta1 * m_tail[k] + (1.0 - beta1) * gk;
        let vk = beta2 * v_tail[k] + (1.0 - beta2) * gk * gk;
        m_tail[k] = mk;
        v_tail[k] = vk;
        o_tail[k] = (mk / bc1) / ((vk / bc2).sqrt() + eps);
    }
}

/// Chunked fused Adam step (the f32-state fast path of
/// [`AdamState::apply`] / [`AdamState::apply_no_decay`]). `decay`
/// selects between `p -= lr·(upd + wd·p)` (the full-rank baseline's
/// historical formula, applied even at wd = 0) and `p -= lr·upd` (the
/// FRUGAL path, which never decayed); the branch is hoisted out of the
/// lane loop.
#[allow(clippy::too_many_arguments)]
fn adam_apply_kernel(
    m: &mut [f32],
    v: &mut [f32],
    p: &mut [f32],
    g: &[f32],
    lr: f32,
    cfg: &AdamCfg,
    bc1: f32,
    bc2: f32,
    decay: bool,
) {
    let (beta1, beta2, eps, wd) = (cfg.beta1, cfg.beta2, cfg.eps, cfg.weight_decay);
    let n = g.len();
    let split = n - n % ADAM_CHUNK;
    let (m_main, m_tail) = m.split_at_mut(split);
    let (v_main, v_tail) = v.split_at_mut(split);
    let (p_main, p_tail) = p.split_at_mut(split);
    let (g_main, g_tail) = g.split_at(split);
    let chunks = m_main
        .chunks_exact_mut(ADAM_CHUNK)
        .zip(v_main.chunks_exact_mut(ADAM_CHUNK))
        .zip(p_main.chunks_exact_mut(ADAM_CHUNK))
        .zip(g_main.chunks_exact(ADAM_CHUNK));
    if decay {
        for (((mc, vc), pc), gc) in chunks {
            for k in 0..ADAM_CHUNK {
                let gk = gc[k];
                let mk = beta1 * mc[k] + (1.0 - beta1) * gk;
                let vk = beta2 * vc[k] + (1.0 - beta2) * gk * gk;
                mc[k] = mk;
                vc[k] = vk;
                let upd = (mk / bc1) / ((vk / bc2).sqrt() + eps) + wd * pc[k];
                pc[k] -= lr * upd;
            }
        }
        for k in 0..m_tail.len() {
            let gk = g_tail[k];
            let mk = beta1 * m_tail[k] + (1.0 - beta1) * gk;
            let vk = beta2 * v_tail[k] + (1.0 - beta2) * gk * gk;
            m_tail[k] = mk;
            v_tail[k] = vk;
            let upd = (mk / bc1) / ((vk / bc2).sqrt() + eps) + wd * p_tail[k];
            p_tail[k] -= lr * upd;
        }
    } else {
        for (((mc, vc), pc), gc) in chunks {
            for k in 0..ADAM_CHUNK {
                let gk = gc[k];
                let mk = beta1 * mc[k] + (1.0 - beta1) * gk;
                let vk = beta2 * vc[k] + (1.0 - beta2) * gk * gk;
                mc[k] = mk;
                vc[k] = vk;
                pc[k] -= lr * ((mk / bc1) / ((vk / bc2).sqrt() + eps));
            }
        }
        for k in 0..m_tail.len() {
            let gk = g_tail[k];
            let mk = beta1 * m_tail[k] + (1.0 - beta1) * gk;
            let vk = beta2 * v_tail[k] + (1.0 - beta2) * gk * gk;
            m_tail[k] = mk;
            v_tail[k] = vk;
            p_tail[k] -= lr * ((mk / bc1) / ((vk / bc2).sqrt() + eps));
        }
    }
}

/// Full-rank AdamW over the whole flat vector.
pub struct AdamW {
    cfg: AdamCfg,
    state: AdamState,
}

impl AdamW {
    pub fn new(n: usize, cfg: AdamCfg) -> Self {
        AdamW { cfg, state: AdamState::new(n) }
    }
}

impl Optimizer for AdamW {
    fn name(&self) -> String {
        "adamw".into()
    }

    fn step(&mut self, params: &mut [f32], grads: &[f32], lr: f32) {
        self.state.apply(params, grads, lr, &self.cfg);
    }

    fn state_floats(&self) -> usize {
        self.state.floats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Golden single-lane trace cross-checked against the python oracle
    /// (`kernels/ref.py::adamw_ref`) — same math, two languages.
    #[test]
    fn golden_scalar_trace() {
        let cfg = AdamCfg::default();
        let mut st = AdamState::new(1);
        let mut p = vec![1.0f32];
        // step 1, g = 0.5: m=0.05, v=2.5e-4, bc1=0.1, bc2=1e-3
        // upd = 0.5/(0.5+1e-8) ~= 1.0 -> p = 1 - 0.1*1.0
        st.apply(&mut p, &[0.5], 0.1, &cfg);
        assert!((p[0] - 0.9).abs() < 1e-4, "p={}", p[0]);
        // direction follows the sign of a persistent gradient
        st.apply(&mut p, &[0.5], 0.1, &cfg);
        assert!(p[0] < 0.9);
    }

    #[test]
    fn converges_on_quadratic() {
        // min 0.5 * ||x - c||^2
        let c = [3.0f32, -2.0, 0.5, 8.0];
        let mut x = vec![0.0f32; 4];
        let mut opt = AdamW::new(4, AdamCfg::default());
        for _ in 0..2000 {
            let g: Vec<f32> = x.iter().zip(&c).map(|(xi, ci)| xi - ci).collect();
            opt.step(&mut x, &g, 0.05);
        }
        for (xi, ci) in x.iter().zip(&c) {
            assert!((xi - ci).abs() < 0.05, "x={xi} c={ci}");
        }
    }

    #[test]
    fn weight_decay_shrinks_params() {
        let cfg = AdamCfg { weight_decay: 0.1, ..Default::default() };
        let mut p = vec![10.0f32];
        let mut st = AdamState::new(1);
        // zero gradient: only decay acts
        for _ in 0..10 {
            st.apply(&mut p, &[0.0], 0.1, &cfg);
        }
        assert!(p[0] < 10.0 && p[0] > 8.0);
    }

    #[test]
    fn bf16_state_quantizes() {
        let cfg = AdamCfg { bf16_state: true, ..Default::default() };
        let mut st = AdamState::new(1);
        let mut out = vec![0.0f32];
        st.update_into(&[0.3], &cfg, &mut out);
        assert_eq!(st.m[0], bf16_round(st.m[0]));
        assert_eq!(st.v[0], bf16_round(st.v[0]));
    }

    #[test]
    fn state_floats_counts_m_and_v() {
        let opt = AdamW::new(100, AdamCfg::default());
        assert_eq!(opt.state_floats(), 200);
    }

    /// The chunked kernels are a loop-shape change only: across lengths
    /// that exercise both the 8-lane body and the scalar tail, every m,
    /// v, and parameter bit must equal the plain scalar recurrence.
    #[test]
    fn chunked_kernels_match_scalar_reference_bitwise() {
        let cfg = AdamCfg { weight_decay: 0.01, ..Default::default() };
        for n in [1usize, 7, 8, 9, 63, 64, 200] {
            let g: Vec<f32> = (0..n).map(|i| ((i % 13) as f32 - 6.0) * 0.17).collect();
            // Scalar reference: the historical per-element loop.
            let mut rm = vec![0.0f32; n];
            let mut rv = vec![0.0f32; n];
            let mut rp = vec![0.5f32; n];
            let mut rt = 0u64;
            for _ in 0..3 {
                rt += 1;
                let bc1 = 1.0 - cfg.beta1.powi(rt as i32);
                let bc2 = 1.0 - cfg.beta2.powi(rt as i32);
                for i in 0..n {
                    let m = cfg.beta1 * rm[i] + (1.0 - cfg.beta1) * g[i];
                    let v = cfg.beta2 * rv[i] + (1.0 - cfg.beta2) * g[i] * g[i];
                    rm[i] = m;
                    rv[i] = v;
                    let upd =
                        (m / bc1) / ((v / bc2).sqrt() + cfg.eps) + cfg.weight_decay * rp[i];
                    rp[i] -= 0.01 * upd;
                }
            }
            let mut st = AdamState::new(n);
            let mut p = vec![0.5f32; n];
            for _ in 0..3 {
                st.apply(&mut p, &g, 0.01, &cfg);
            }
            let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(&p), bits(&rp), "params n={n}");
            assert_eq!(bits(&st.m), bits(&rm), "m n={n}");
            assert_eq!(bits(&st.v), bits(&rv), "v n={n}");
        }
    }

    /// `apply_no_decay` must equal `update_into` + the explicit
    /// `p -= lr * out` sweep bit-for-bit — it is the fused form of
    /// FRUGAL's historical two-pass state-full update.
    #[test]
    fn fused_no_decay_matches_two_pass_bitwise() {
        let cfg = AdamCfg { weight_decay: 0.1, ..Default::default() }; // decay must be IGNORED
        let n = 37;
        let g: Vec<f32> = (0..n).map(|i| ((i * 7 % 11) as f32 - 5.0) * 0.3).collect();
        let mut st_a = AdamState::new(n);
        let mut p_a = vec![1.0f32; n];
        let mut st_b = AdamState::new(n);
        let mut p_b = vec![1.0f32; n];
        let mut out = vec![0.0f32; n];
        for _ in 0..4 {
            st_a.apply_no_decay(&mut p_a, &g, 0.02, &cfg);
            st_b.update_into(&g, &cfg, &mut out);
            for i in 0..n {
                p_b[i] -= 0.02 * out[i];
            }
        }
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&p_a), bits(&p_b));
        assert_eq!(bits(&st_a.m), bits(&st_b.m));
        assert_eq!(st_a.t, st_b.t);
    }

    #[test]
    fn reset_zeroes_and_restarts_bias_correction() {
        let cfg = AdamCfg::default();
        let mut st = AdamState::new(2);
        let mut out = vec![0.0f32; 2];
        st.update_into(&[1.0, -1.0], &cfg, &mut out);
        assert_eq!(st.t, 1);
        st.reset();
        assert_eq!(st.t, 0);
        assert!(st.m.iter().all(|&x| x == 0.0));
        assert!(st.v.iter().all(|&x| x == 0.0));
    }
}
