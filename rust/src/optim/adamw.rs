//! AdamW — the memory-hungry upper-bound baseline (paper Table 2 row 1)
//! and the state-full update rule inside FRUGAL/BAdam/GaLore.


use super::Optimizer;
use crate::tensor::bf16_round;

/// Adam hyper-parameters (paper §A.1 defaults).
#[derive(Clone, Copy, Debug)]
pub struct AdamCfg {
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
    /// Store m/v (and round updates) through bf16 — the "pure bf16"
    /// regime of paper Tables 3/9.
    pub bf16_state: bool,
}

impl Default for AdamCfg {
    fn default() -> Self {
        AdamCfg { beta1: 0.9, beta2: 0.999, eps: 1e-8, weight_decay: 0.0, bf16_state: false }
    }
}

impl AdamCfg {
    /// The paper's Table 8 ablation value.
    pub fn beta2_095() -> Self {
        AdamCfg { beta2: 0.95, ..Default::default() }
    }
}

/// Reusable Adam state over an arbitrary number of lanes. Shared by every
/// optimizer that embeds an Adam-style update (FRUGAL, GaLore, BAdam, …).
#[derive(Clone, Debug)]
pub struct AdamState {
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    /// Per-buffer step count for bias correction (resets with the buffer —
    /// the correct behaviour after a subspace change, §D).
    pub t: u64,
}

impl AdamState {
    pub fn new(n: usize) -> Self {
        AdamState { m: vec![0.0; n], v: vec![0.0; n], t: 0 }
    }

    pub fn reset(&mut self) {
        self.m.iter_mut().for_each(|x| *x = 0.0);
        self.v.iter_mut().for_each(|x| *x = 0.0);
        self.t = 0;
    }

    /// Advance state on `grads` and write the (unscaled-by-lr) update into
    /// `out`: out[i] = m̂ / (sqrt(v̂) + eps). Returns nothing; caller applies
    /// `p -= lr * (out + wd * p)`.
    pub fn update_into(&mut self, grads: &[f32], cfg: &AdamCfg, out: &mut [f32]) {
        debug_assert_eq!(grads.len(), self.m.len());
        debug_assert_eq!(out.len(), self.m.len());
        self.t += 1;
        let bc1 = 1.0 - cfg.beta1.powi(self.t as i32);
        let bc2 = 1.0 - cfg.beta2.powi(self.t as i32);
        for i in 0..grads.len() {
            let g = grads[i];
            let mut m = cfg.beta1 * self.m[i] + (1.0 - cfg.beta1) * g;
            let mut v = cfg.beta2 * self.v[i] + (1.0 - cfg.beta2) * g * g;
            if cfg.bf16_state {
                m = bf16_round(m);
                v = bf16_round(v);
            }
            self.m[i] = m;
            self.v[i] = v;
            out[i] = (m / bc1) / ((v / bc2).sqrt() + cfg.eps);
        }
    }

    /// Fused apply: `p -= lr * (adam_update + wd * p)` without a scratch
    /// buffer — the hot path used by the full-rank baseline.
    pub fn apply(&mut self, params: &mut [f32], grads: &[f32], lr: f32, cfg: &AdamCfg) {
        debug_assert_eq!(grads.len(), self.m.len());
        self.t += 1;
        let bc1 = 1.0 - cfg.beta1.powi(self.t as i32);
        let bc2 = 1.0 - cfg.beta2.powi(self.t as i32);
        for i in 0..grads.len() {
            let g = grads[i];
            let mut m = cfg.beta1 * self.m[i] + (1.0 - cfg.beta1) * g;
            let mut v = cfg.beta2 * self.v[i] + (1.0 - cfg.beta2) * g * g;
            if cfg.bf16_state {
                m = bf16_round(m);
                v = bf16_round(v);
            }
            self.m[i] = m;
            self.v[i] = v;
            let upd = (m / bc1) / ((v / bc2).sqrt() + cfg.eps) + cfg.weight_decay * params[i];
            params[i] -= lr * upd;
        }
    }

    pub fn floats(&self) -> usize {
        self.m.len() + self.v.len()
    }
}

/// Full-rank AdamW over the whole flat vector.
pub struct AdamW {
    cfg: AdamCfg,
    state: AdamState,
}

impl AdamW {
    pub fn new(n: usize, cfg: AdamCfg) -> Self {
        AdamW { cfg, state: AdamState::new(n) }
    }
}

impl Optimizer for AdamW {
    fn name(&self) -> String {
        "adamw".into()
    }

    fn step(&mut self, params: &mut [f32], grads: &[f32], lr: f32) {
        self.state.apply(params, grads, lr, &self.cfg);
    }

    fn state_floats(&self) -> usize {
        self.state.floats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Golden single-lane trace cross-checked against the python oracle
    /// (`kernels/ref.py::adamw_ref`) — same math, two languages.
    #[test]
    fn golden_scalar_trace() {
        let cfg = AdamCfg::default();
        let mut st = AdamState::new(1);
        let mut p = vec![1.0f32];
        // step 1, g = 0.5: m=0.05, v=2.5e-4, bc1=0.1, bc2=1e-3
        // upd = 0.5/(0.5+1e-8) ~= 1.0 -> p = 1 - 0.1*1.0
        st.apply(&mut p, &[0.5], 0.1, &cfg);
        assert!((p[0] - 0.9).abs() < 1e-4, "p={}", p[0]);
        // direction follows the sign of a persistent gradient
        st.apply(&mut p, &[0.5], 0.1, &cfg);
        assert!(p[0] < 0.9);
    }

    #[test]
    fn converges_on_quadratic() {
        // min 0.5 * ||x - c||^2
        let c = [3.0f32, -2.0, 0.5, 8.0];
        let mut x = vec![0.0f32; 4];
        let mut opt = AdamW::new(4, AdamCfg::default());
        for _ in 0..2000 {
            let g: Vec<f32> = x.iter().zip(&c).map(|(xi, ci)| xi - ci).collect();
            opt.step(&mut x, &g, 0.05);
        }
        for (xi, ci) in x.iter().zip(&c) {
            assert!((xi - ci).abs() < 0.05, "x={xi} c={ci}");
        }
    }

    #[test]
    fn weight_decay_shrinks_params() {
        let cfg = AdamCfg { weight_decay: 0.1, ..Default::default() };
        let mut p = vec![10.0f32];
        let mut st = AdamState::new(1);
        // zero gradient: only decay acts
        for _ in 0..10 {
            st.apply(&mut p, &[0.0], 0.1, &cfg);
        }
        assert!(p[0] < 10.0 && p[0] > 8.0);
    }

    #[test]
    fn bf16_state_quantizes() {
        let cfg = AdamCfg { bf16_state: true, ..Default::default() };
        let mut st = AdamState::new(1);
        let mut out = vec![0.0f32];
        st.update_into(&[0.3], &cfg, &mut out);
        assert_eq!(st.m[0], bf16_round(st.m[0]));
        assert_eq!(st.v[0], bf16_round(st.v[0]));
    }

    #[test]
    fn state_floats_counts_m_and_v() {
        let opt = AdamW::new(100, AdamCfg::default());
        assert_eq!(opt.state_floats(), 200);
    }

    #[test]
    fn reset_zeroes_and_restarts_bias_correction() {
        let cfg = AdamCfg::default();
        let mut st = AdamState::new(2);
        let mut out = vec![0.0f32; 2];
        st.update_into(&[1.0, -1.0], &cfg, &mut out);
        assert_eq!(st.t, 1);
        st.reset();
        assert_eq!(st.t, 0);
        assert!(st.m.iter().all(|&x| x == 0.0));
        assert!(st.v.iter().all(|&x| x == 0.0));
    }
}
