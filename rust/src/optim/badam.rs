//! BAdam (Luo et al., 2024) — block coordinate descent baseline.
//!
//! Parameters are divided into blocks (transformer layers); every `T`
//! steps the active block changes and is updated with AdamW while all
//! other Linear blocks are **frozen**. Non-Linear roles get full Adam as
//! in all our baselines (paper §A.1). The difference from FRUGAL is
//! exactly that frozen blocks receive no state-free update.


use crate::util::Prng;

use super::adamw::{AdamCfg, AdamState};
use super::frugal::BlockPolicy;
use super::{Layout, Optimizer, Role};

#[derive(Clone, Debug)]
pub struct BAdamCfg {
    /// Fraction of Linear parameters active at once (paper ρ = a_block/p).
    pub rho: f32,
    pub update_freq: u64,
    pub adam: AdamCfg,
    pub policy: BlockPolicy,
    pub seed: u64,
}

impl Default for BAdamCfg {
    fn default() -> Self {
        BAdamCfg {
            rho: 0.25,
            update_freq: 200,
            adam: AdamCfg::default(),
            policy: BlockPolicy::Ascending,
            seed: 0,
        }
    }
}

pub struct BAdam {
    pub cfg: BAdamCfg,
    layout: Layout,
    /// Adam state for active Linear blocks (keyed by param index).
    lin: Vec<Option<AdamState>>,
    role_state: Vec<Option<AdamState>>,
    step_count: u64,
    cursor: usize,
    rng: Prng,
}

impl BAdam {
    pub fn new(layout: Layout, cfg: BAdamCfg) -> Self {
        let n = layout.params.len();
        let rng = Prng::seed_from_u64(cfg.seed);
        let mut role_state: Vec<Option<AdamState>> = (0..n).map(|_| None).collect();
        for (i, p) in layout.params.iter().enumerate() {
            if p.role != Role::Linear {
                role_state[i] = Some(AdamState::new(p.numel()));
            }
        }
        BAdam {
            cfg,
            layout,
            lin: (0..n).map(|_| None).collect(),
            role_state,
            step_count: 0,
            cursor: 0,
            rng,
        }
    }

    fn reselect(&mut self) {
        let linear_idx: Vec<usize> = (0..self.layout.params.len())
            .filter(|&i| self.layout.params[i].role == Role::Linear)
            .collect();
        let total: usize = linear_idx.iter().map(|&i| self.layout.params[i].numel()).sum();
        let target = (self.cfg.rho as f64 * total as f64).round() as usize;
        let mut order = linear_idx.clone();
        match self.cfg.policy {
            BlockPolicy::Random => self.rng.shuffle(&mut order),
            BlockPolicy::Ascending => { let n = order.len().max(1); order.rotate_left(self.cursor % n) },
            BlockPolicy::Descending => {
                order.reverse();
                { let n = order.len().max(1); order.rotate_left(self.cursor % n) };
            }
        }
        // Free all previous state (paper Alg. 4 block_step: state of
        // deactivated blocks is cleared to save memory).
        for s in self.lin.iter_mut() {
            *s = None;
        }
        let mut acc = 0usize;
        let mut picked = 0usize;
        for &i in &order {
            if acc >= target {
                break;
            }
            self.lin[i] = Some(AdamState::new(self.layout.params[i].numel()));
            acc += self.layout.params[i].numel();
            picked += 1;
        }
        self.cursor = (self.cursor + picked.max(1)) % linear_idx.len().max(1);
    }
}

impl Optimizer for BAdam {
    fn name(&self) -> String {
        format!("badam(rho={})", self.cfg.rho)
    }

    fn step(&mut self, params: &mut [f32], grads: &[f32], lr: f32) {
        if self.step_count % self.cfg.update_freq == 0 {
            self.reselect();
        }
        self.step_count += 1;
        let adam = self.cfg.adam;
        for i in 0..self.layout.params.len() {
            let p = self.layout.params[i].clone();
            let range = p.offset..p.offset + p.numel();
            let g = &grads[range.clone()];
            if p.role != Role::Linear {
                self.role_state[i].as_mut().unwrap().apply(&mut params[range], g, lr, &adam);
            } else if let Some(st) = self.lin[i].as_mut() {
                st.apply(&mut params[range], g, lr, &adam);
            }
            // frozen block: no update at all
        }
    }

    fn state_floats(&self) -> usize {
        let role: usize = self.role_state.iter().flatten().map(|s| s.floats()).sum();
        let lin: usize = self.lin.iter().flatten().map(|s| s.floats()).sum();
        role + lin
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layout() -> Layout {
        Layout::synthetic(32, 8, 20, 4)
    }

    fn grads(l: &Layout, seed: u64) -> Vec<f32> {
        let mut rng = Prng::seed_from_u64(seed);
        let mut g = vec![0.0f32; l.padded_size];
        for v in g[..l.flat_size].iter_mut() {
            *v = crate::tensor::matrix::normal_sample(&mut rng) * 0.1;
        }
        g
    }

    #[test]
    fn inactive_blocks_frozen() {
        let l = layout();
        let mut opt = BAdam::new(l.clone(), BAdamCfg { rho: 0.25, ..Default::default() });
        let g = grads(&l, 0);
        let mut p = vec![0.5f32; l.padded_size];
        opt.step(&mut p, &g, 1e-3);
        let mut frozen = 0usize;
        let mut moved = 0usize;
        for info in l.linears() {
            let any = (info.offset..info.offset + info.numel()).any(|x| p[x] != 0.5);
            if any {
                moved += 1;
            } else {
                frozen += 1;
            }
        }
        assert!(moved >= 1);
        assert!(frozen > moved, "rho=0.25 should freeze most blocks");
    }

    #[test]
    fn state_matches_active_mass() {
        let l = layout();
        let mut opt = BAdam::new(l.clone(), BAdamCfg { rho: 0.25, ..Default::default() });
        let g = grads(&l, 1);
        let mut p = vec![0.0f32; l.padded_size];
        opt.step(&mut p, &g, 1e-3);
        let role: usize =
            l.params.iter().filter(|p| p.role != Role::Linear).map(|p| p.numel()).sum();
        let lin_state = opt.state_floats() - 2 * role;
        let expect = (2.0 * 0.25 * l.linear_numel() as f32) as usize;
        assert!(
            (lin_state as f32 - expect as f32).abs() / expect as f32 <= 0.5,
            "lin_state={lin_state} expect~{expect}"
        );
    }

    #[test]
    fn ascending_policy_cycles_through_all_blocks() {
        let l = layout();
        let n_lin = l.linears().count();
        let mut opt = BAdam::new(
            l.clone(),
            BAdamCfg { rho: 1.0 / n_lin as f32, update_freq: 1, ..Default::default() },
        );
        let g = grads(&l, 2);
        let mut p = vec![0.0f32; l.padded_size];
        let mut seen = std::collections::HashSet::new();
        for _ in 0..n_lin * 2 {
            opt.step(&mut p, &g, 1e-3);
            for (i, s) in opt.lin.iter().enumerate() {
                if s.is_some() {
                    seen.insert(i);
                }
            }
        }
        assert_eq!(seen.len(), n_lin, "cycling must visit every block");
    }

    #[test]
    fn non_linear_roles_always_updated() {
        let l = layout();
        let mut opt = BAdam::new(l.clone(), BAdamCfg { rho: 0.0, ..Default::default() });
        let g = grads(&l, 3);
        let mut p = vec![0.5f32; l.padded_size];
        opt.step(&mut p, &g, 1e-3);
        let emb = l.params.iter().find(|p| p.role == Role::Embed).unwrap();
        let any = (emb.offset..emb.offset + emb.numel()).any(|x| p[x] != 0.5);
        assert!(any, "embeddings must train even at rho=0");
    }
}
