//! Fira (Chen et al., 2024a) — concurrent full-rank method (paper §B.1).
//!
//! GaLore-style SVD projection + Adam in the low-rank space, but the
//! residual gradient is NOT discarded: it is applied SGD-like, scaled
//! per-column by ‖ψ(Rt)‖/‖Rt‖ where ψ is the Adam update map — Fira's
//! norm-based scaling. A norm-growth limiter replaces gradient clipping.
//! Follows GaLore in *keeping* stale state across projector updates (the
//! suboptimality paper §D points out).

use super::adamw::{AdamCfg, AdamState};
use super::projection::{MatrixProjector, Side};
use super::{Layout, Optimizer, Role};
use crate::tensor::Matrix;

#[derive(Clone, Debug)]
pub struct FiraCfg {
    pub rho: f32,
    pub update_freq: u64,
    pub adam: AdamCfg,
    /// Norm-growth limiter threshold γ: successive residual-norm ratio is
    /// capped at this value (Fira §3.3; default 1.01 in their code).
    pub limiter_gamma: f32,
}

impl Default for FiraCfg {
    fn default() -> Self {
        FiraCfg { rho: 0.25, update_freq: 200, adam: AdamCfg::default(), limiter_gamma: 1.01 }
    }
}

struct FiraState {
    proj: MatrixProjector,
    adam: AdamState,
    /// Previous residual norm for the norm-growth limiter.
    prev_resid_norm: f32,
}

pub struct Fira {
    pub cfg: FiraCfg,
    layout: Layout,
    lin: Vec<Option<FiraState>>,
    role_state: Vec<Option<AdamState>>,
    step_count: u64,
    scratch: Vec<f32>,
}

impl Fira {
    pub fn new(layout: Layout, cfg: FiraCfg) -> Self {
        let n = layout.params.len();
        let mut role_state: Vec<Option<AdamState>> = (0..n).map(|_| None).collect();
        for (i, p) in layout.params.iter().enumerate() {
            if p.role != Role::Linear {
                role_state[i] = Some(AdamState::new(p.numel()));
            }
        }
        Fira { cfg, layout, lin: (0..n).map(|_| None).collect(), role_state, step_count: 0,
               scratch: Vec::new() }
    }
}

impl Optimizer for Fira {
    fn name(&self) -> String {
        format!("fira(rho={})", self.cfg.rho)
    }

    fn step(&mut self, params: &mut [f32], grads: &[f32], lr: f32) {
        let refresh = self.step_count % self.cfg.update_freq == 0;
        self.step_count += 1;
        let adam_cfg = self.cfg.adam;
        for i in 0..self.layout.params.len() {
            let p = self.layout.params[i].clone();
            let range = p.offset..p.offset + p.numel();
            let g = &grads[range.clone()];
            if p.role != Role::Linear {
                self.role_state[i].as_mut().unwrap().apply(&mut params[range], g, lr, &adam_cfg);
                continue;
            }
            let (rows, cols) = p.dims();
            let gm = Matrix::from_vec(rows, cols, g.to_vec());
            if refresh || self.lin[i].is_none() {
                let r = ((self.cfg.rho * rows.min(cols) as f32).round() as usize).max(1);
                let proj = MatrixProjector::from_svd(&gm, r);
                let state_n = match proj.side {
                    Side::Left => proj.rank() * cols,
                    Side::Right => rows * proj.rank(),
                };
                // Fira keeps stale Adam state like GaLore (resize = reset
                // only on first allocation; rank is constant afterwards).
                let adam = match self.lin[i].take() {
                    Some(old) if old.adam.m.len() == state_n => old.adam,
                    _ => AdamState::new(state_n),
                };
                self.lin[i] = Some(FiraState { proj, adam, prev_resid_norm: f32::INFINITY });
            }
            let st = self.lin[i].as_mut().unwrap();
            let low = st.proj.down(&gm);
            self.scratch.clear();
            self.scratch.resize(low.data.len(), 0.0);
            st.adam.update_into(&low.data, &adam_cfg, &mut self.scratch);
            let low_upd = Matrix::from_vec(low.rows, low.cols, self.scratch.clone());
            let full_upd = st.proj.up(&low_upd);

            // Residual R_t = G - P P^T G and Fira's norm-based scaling:
            // scale = ||psi(G_low)|| / ||G_low|| applied to R_t.
            let back = st.proj.up(&low);
            let mut resid = gm.sub(&back);
            let low_norm = crate::tensor::norm(&low.data);
            let upd_norm = crate::tensor::norm(&low_upd.data);
            let scale = if low_norm > 1e-12 { upd_norm / low_norm } else { 0.0 };

            // Norm-growth limiter (replaces gradient clipping).
            let rnorm = resid.frobenius_norm();
            if rnorm > self.cfg.limiter_gamma * st.prev_resid_norm {
                let cap = self.cfg.limiter_gamma * st.prev_resid_norm / rnorm;
                crate::tensor::scale(&mut resid.data, cap);
            }
            st.prev_resid_norm = rnorm.min(st.prev_resid_norm * self.cfg.limiter_gamma);
            if !st.prev_resid_norm.is_finite() {
                st.prev_resid_norm = rnorm;
            }

            let prm = &mut params[range];
            for lane in 0..prm.len() {
                prm[lane] -= lr * (full_upd.data[lane] + scale * resid.data[lane]);
            }
        }
    }

    fn state_floats(&self) -> usize {
        let role: usize = self.role_state.iter().flatten().map(|s| s.floats()).sum();
        let lin: usize = self
            .lin
            .iter()
            .flatten()
            .map(|s| s.adam.floats() + s.proj.floats() + 1)
            .sum();
        role + lin
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    
    use crate::util::Prng;

    fn layout() -> Layout {
        Layout::synthetic(32, 8, 20, 2)
    }

    fn grads(l: &Layout, seed: u64) -> Vec<f32> {
        let mut rng = Prng::seed_from_u64(seed);
        let mut g = vec![0.0f32; l.padded_size];
        for v in g[..l.flat_size].iter_mut() {
            *v = crate::tensor::matrix::normal_sample(&mut rng) * 0.1;
        }
        g
    }

    #[test]
    fn updates_are_full_rank() {
        // Unlike GaLore, Fira's update includes the residual: full rank.
        let l = layout();
        let mut opt = Fira::new(l.clone(), FiraCfg::default());
        let g = grads(&l, 0);
        let mut p = vec![0.0f32; l.padded_size];
        opt.step(&mut p, &g, 1e-3);
        let info = l.linears().next().unwrap();
        let (rows, cols) = info.dims();
        let upd =
            Matrix::from_vec(rows, cols, p[info.offset..info.offset + info.numel()].to_vec());
        let s = crate::linalg::svd(&upd).s;
        let r = ((0.25 * rows.min(cols) as f32).round() as usize).max(1);
        // Singular values beyond rank r remain non-negligible.
        assert!(
            s[r] > 1e-3 * s[0],
            "residual update missing: {s:?}"
        );
    }

    #[test]
    fn limiter_caps_residual_growth() {
        let l = layout();
        let mut opt = Fira::new(l.clone(), FiraCfg { limiter_gamma: 1.01, ..Default::default() });
        let mut p = vec![0.0f32; l.padded_size];
        // First step with small grads, then a 100x spike.
        let g_small = grads(&l, 1);
        opt.step(&mut p, &g_small, 1e-3);
        let p_before = p.clone();
        let g_big: Vec<f32> = g_small.iter().map(|x| x * 100.0).collect();
        opt.step(&mut p, &g_big, 1e-3);
        // The applied update must be far smaller than the naive 100x one.
        let delta: f32 = p
            .iter()
            .zip(&p_before)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f32>()
            .sqrt();
        assert!(delta.is_finite());
        // Crude bound: without the limiter the linear-lane delta would be
        // ~100x the small-step delta; we require < 50x.
        let small_delta: f32 = p_before.iter().map(|x| x * x).sum::<f32>().sqrt();
        assert!(delta < 50.0 * small_delta.max(1e-6), "delta={delta}");
    }

    #[test]
    fn converges_on_quadratic() {
        let l = layout();
        let mut opt = Fira::new(l.clone(), FiraCfg { update_freq: 5, ..Default::default() });
        let mut p = grads(&l, 2);
        let n0: f32 = p.iter().map(|x| x * x).sum();
        for _ in 0..50 {
            let g = p.clone();
            opt.step(&mut p, &g, 1e-2);
        }
        let n1: f32 = p.iter().map(|x| x * x).sum();
        assert!(n1 < n0);
    }
}
