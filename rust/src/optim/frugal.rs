//! FRUGAL — Full-Rank Updates with GrAdient spLitting (paper Alg. 1/4).
//!
//! Every step, the flat space is split into a *state-full* subspace
//! (updated by an advanced rule — AdamW by default) and the complementary
//! *state-free* subspace (updated by signSGD by default), so the update is
//! full-rank while state memory scales with ρ. Every `T` steps the
//! state-full subspace is re-selected (blockwise / columnwise / RandK /
//! SVD / random semi-orthogonal) and the state of evicted lanes is
//! **released** — the reset semantics the paper shows are required (§4,
//! §D).
//!
//! Module roles: parameters whose role is in `statefull_roles`
//! (default: Embed, Norm, Output — paper §A.1) keep persistent full state
//! and never enter the projection game; Linear parameters are the
//! projectable set. Table 4's module-sensitivity experiment is run by
//! shrinking `statefull_roles`.


use crate::util::Prng;

use super::adamw::{AdamCfg, AdamState};
use super::lion::{LionCfg, LionState};
use super::projection::{column_subset, randk_indices, MatrixProjector};
use super::sgd::sign_step;
use super::{Layout, Optimizer, Role};
use crate::tensor::Matrix;

/// How the state-full subspace is chosen (paper Table 1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ProjectionKind {
    /// Whole matrices in/out (the paper's default — most memory-efficient).
    Blockwise,
    /// Random column subsets per matrix (used for fine-tuning, §7).
    Columnwise,
    /// Random coordinate subsets per matrix (seed-reconstructible, §C).
    RandK,
    /// Top-r SVD subspace of the current gradient (GaLore-like).
    Svd,
    /// Random semi-orthogonal subspace (paper §3.1 "Random").
    Random,
}

/// Block traversal policy for Blockwise selection (paper Table 13).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BlockPolicy {
    Random,
    Ascending,
    Descending,
}

/// The state-full update rule (paper Tables 2/11).
#[derive(Clone, Copy, Debug)]
pub enum StateFullKind {
    AdamW(AdamCfg),
    Lion(LionCfg),
    Sgdm { beta: f32 },
}

/// The state-free update rule (paper Table 10).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StateFreeKind {
    SignSgd,
    Sgd,
    /// Freeze the state-free subspace — turns FRUGAL into its low-rank
    /// ancestors (the "Optimizes state-free subspace: No" rows of Table 1).
    Frozen,
}

#[derive(Clone, Debug)]
pub struct FrugalCfg {
    /// Fraction of Linear parameters in the state-full subspace (paper ρ).
    pub rho: f32,
    /// Subspace update frequency T (paper Table 14; default 200).
    pub update_freq: u64,
    pub projection: ProjectionKind,
    pub block_policy: BlockPolicy,
    pub state_full: StateFullKind,
    pub state_free: StateFreeKind,
    /// lr_free = lr * lr_free_mult (1.0 for pre-training §A.1, 0.1 for
    /// fine-tuning §A.2).
    pub lr_free_mult: f32,
    /// Roles with persistent full state (paper default: all non-Linear).
    pub statefull_roles: Vec<Role>,
    /// Roles excluded from training entirely (RoBERTa ρ=0 freezes
    /// embeddings, §7.1).
    pub frozen_roles: Vec<Role>,
    pub seed: u64,
}

impl Default for FrugalCfg {
    fn default() -> Self {
        FrugalCfg {
            rho: 0.25,
            update_freq: 200,
            projection: ProjectionKind::Blockwise,
            block_policy: BlockPolicy::Random,
            state_full: StateFullKind::AdamW(AdamCfg::default()),
            state_free: StateFreeKind::SignSgd,
            lr_free_mult: 1.0,
            statefull_roles: vec![Role::Embed, Role::Norm, Role::Output],
            frozen_roles: vec![],
            seed: 0,
        }
    }
}

/// Generic state-full rule state, allocated per active region.
#[derive(Clone, Debug)]
enum FullState {
    Adam(AdamState),
    Lion(LionState),
    Sgdm(Vec<f32>),
}

impl FullState {
    fn new(kind: &StateFullKind, n: usize) -> Self {
        match kind {
            StateFullKind::AdamW(_) => FullState::Adam(AdamState::new(n)),
            StateFullKind::Lion(_) => FullState::Lion(LionState::new(n)),
            StateFullKind::Sgdm { .. } => FullState::Sgdm(vec![0.0; n]),
        }
    }

    /// Advance state on `grads` and write the unscaled update direction
    /// (to be multiplied by lr) into `out`.
    fn update_into(&mut self, kind: &StateFullKind, grads: &[f32], out: &mut [f32]) {
        match (self, kind) {
            (FullState::Adam(st), StateFullKind::AdamW(cfg)) => st.update_into(grads, cfg, out),
            (FullState::Lion(st), StateFullKind::Lion(cfg)) => {
                for i in 0..grads.len() {
                    let interp = cfg.beta1 * st.m[i] + (1.0 - cfg.beta1) * grads[i];
                    out[i] = if interp > 0.0 {
                        1.0
                    } else if interp < 0.0 {
                        -1.0
                    } else {
                        0.0
                    };
                    st.m[i] = cfg.beta2 * st.m[i] + (1.0 - cfg.beta2) * grads[i];
                }
            }
            (FullState::Sgdm(m), StateFullKind::Sgdm { beta }) => {
                for i in 0..grads.len() {
                    m[i] = (1.0 - beta) * grads[i] + beta * m[i];
                    out[i] = m[i];
                }
            }
            _ => unreachable!("state/kind mismatch"),
        }
    }

    fn floats(&self) -> usize {
        match self {
            FullState::Adam(st) => st.floats(),
            FullState::Lion(st) => st.floats(),
            FullState::Sgdm(m) => m.len(),
        }
    }
}

/// Per-Linear-parameter projection state.
enum LinearState {
    /// Blockwise: whole matrix active (with state) or state-free.
    Block { active: bool, state: Option<FullState> },
    /// Columnwise: sorted active columns, their position map, and state of
    /// size rows×k.
    Columns { cols: Vec<usize>, pos: Vec<i32>, state: FullState },
    /// RandK: seed-derived active indices (bitmap is bookkeeping; the real
    /// system stores only the seed — §C) and state of size k.
    RandK { idx: Vec<usize>, member: Vec<i32>, state: FullState },
    /// Dense rank-r projector; state lives in the low-rank space.
    Projected { proj: MatrixProjector, state: FullState },
}

impl LinearState {
    fn floats(&self) -> usize {
        match self {
            LinearState::Block { state, .. } => state.as_ref().map_or(0, |s| s.floats()),
            LinearState::Columns { state, .. } => state.floats(),
            LinearState::RandK { state, .. } => state.floats(),
            LinearState::Projected { proj, state } => proj.floats() + state.floats(),
        }
    }
}

/// The FRUGAL optimizer over a flat parameter vector.
pub struct Frugal {
    pub cfg: FrugalCfg,
    layout: Layout,
    /// Persistent full state for always-state-full roles, keyed by param
    /// index; `None` for Linear / frozen params.
    role_state: Vec<Option<FullState>>,
    /// Projection state per param index (Linear only).
    linear_state: Vec<Option<LinearState>>,
    step_count: u64,
    round: u64,
    /// Blockwise cycling cursor (Ascending/Descending policies).
    cursor: usize,
    rng: Prng,
    /// Scratch buffers reused across steps (no hot-loop allocation).
    scratch: Vec<f32>,
    scratch2: Vec<f32>,
}

impl Frugal {
    pub fn new(layout: Layout, cfg: FrugalCfg) -> Self {
        let n = layout.params.len();
        let rng = Prng::seed_from_u64(cfg.seed);
        let mut me = Frugal {
            cfg,
            layout,
            role_state: (0..n).map(|_| None).collect(),
            linear_state: (0..n).map(|_| None).collect(),
            step_count: 0,
            round: 0,
            cursor: 0,
            rng,
            scratch: Vec::new(),
            scratch2: Vec::new(),
        };
        for i in 0..n {
            let p = &me.layout.params[i];
            if p.role != Role::Linear
                && me.cfg.statefull_roles.contains(&p.role)
                && !me.cfg.frozen_roles.contains(&p.role)
            {
                me.role_state[i] = Some(FullState::new(&me.cfg.state_full, p.numel()));
            }
        }
        me
    }

    pub fn layout(&self) -> &Layout {
        &self.layout
    }

    /// (Re-)select the state-full subspace. For SVD projection the current
    /// gradient is needed, hence `grads`.
    fn reselect(&mut self, grads: &[f32]) {
        self.round += 1;
        let linear_idx: Vec<usize> = (0..self.layout.params.len())
            .filter(|&i| self.layout.params[i].role == Role::Linear)
            .collect();
        match self.cfg.projection {
            ProjectionKind::Blockwise => self.reselect_blockwise(&linear_idx),
            ProjectionKind::Columnwise => {
                for &i in &linear_idx {
                    let p = &self.layout.params[i];
                    let (rows, cols) = p.dims();
                    let k = ((self.cfg.rho * cols as f32).round() as usize).min(cols);
                    let sel = column_subset(cols, k, &mut self.rng);
                    let mut pos = vec![-1i32; cols];
                    for (j, &c) in sel.iter().enumerate() {
                        pos[c] = j as i32;
                    }
                    self.linear_state[i] = Some(LinearState::Columns {
                        cols: sel,
                        pos,
                        state: FullState::new(&self.cfg.state_full, rows * k),
                    });
                }
            }
            ProjectionKind::RandK => {
                for &i in &linear_idx {
                    let p = &self.layout.params[i];
                    let n = p.numel();
                    let k = ((self.cfg.rho * n as f32).round() as usize).min(n);
                    let seed = self.cfg.seed ^ (self.round << 20) ^ (i as u64);
                    let mut idx = randk_indices(n, k, seed);
                    idx.sort_unstable();
                    let mut member = vec![-1i32; n];
                    for (j, &e) in idx.iter().enumerate() {
                        member[e] = j as i32;
                    }
                    self.linear_state[i] = Some(LinearState::RandK {
                        idx,
                        member,
                        state: FullState::new(&self.cfg.state_full, k),
                    });
                }
            }
            ProjectionKind::Svd | ProjectionKind::Random => {
                for &i in &linear_idx {
                    let p = &self.layout.params[i];
                    let (rows, cols) = p.dims();
                    let r = ((self.cfg.rho * rows.min(cols) as f32).round() as usize).max(1);
                    let proj = if self.cfg.projection == ProjectionKind::Svd {
                        let g = Matrix::from_vec(
                            rows,
                            cols,
                            grads[p.offset..p.offset + p.numel()].to_vec(),
                        );
                        MatrixProjector::from_svd(&g, r)
                    } else {
                        MatrixProjector::random(rows, cols, r, &mut self.rng)
                    };
                    let state_n = if proj.side == super::projection::Side::Left {
                        proj.rank() * cols
                    } else {
                        rows * proj.rank()
                    };
                    self.linear_state[i] = Some(LinearState::Projected {
                        proj,
                        state: FullState::new(&self.cfg.state_full, state_n),
                    });
                }
            }
        }
    }

    fn reselect_blockwise(&mut self, linear_idx: &[usize]) {
        let total: usize = linear_idx.iter().map(|&i| self.layout.params[i].numel()).sum();
        let target = (self.cfg.rho as f64 * total as f64).round() as usize;
        // Order blocks per policy, starting at the cycling cursor so every
        // block is eventually visited (BAdam-style traversal).
        let mut order: Vec<usize> = linear_idx.to_vec();
        match self.cfg.block_policy {
            BlockPolicy::Random => self.rng.shuffle(&mut order),
            BlockPolicy::Ascending => { let n = order.len().max(1); order.rotate_left(self.cursor % n) },
            BlockPolicy::Descending => {
                order.reverse();
                { let n = order.len().max(1); order.rotate_left(self.cursor % n) };
            }
        }
        let mut active = std::collections::HashSet::new();
        let mut acc = 0usize;
        let mut picked = 0usize;
        for &i in &order {
            if acc >= target {
                break;
            }
            active.insert(i);
            acc += self.layout.params[i].numel();
            picked += 1;
        }
        self.cursor = (self.cursor + picked.max(1)) % linear_idx.len().max(1);
        for &i in linear_idx {
            let is_active = active.contains(&i);
            let state = if is_active {
                Some(FullState::new(&self.cfg.state_full, self.layout.params[i].numel()))
            } else {
                None
            };
            self.linear_state[i] = Some(LinearState::Block { active: is_active, state });
        }
    }

    fn state_free_apply(&self, params: &mut [f32], grads: &[f32], lr_free: f32) {
        match self.cfg.state_free {
            StateFreeKind::SignSgd => sign_step(params, grads, lr_free),
            StateFreeKind::Sgd => crate::tensor::axpy(-lr_free, grads, params),
            StateFreeKind::Frozen => {}
        }
    }

    /// Fraction of *Linear* lanes currently in the state-full subspace —
    /// the realized ρ, asserted by the proptest invariants.
    pub fn realized_rho(&self) -> f32 {
        let mut active = 0usize;
        let mut total = 0usize;
        for (i, p) in self.layout.params.iter().enumerate() {
            if p.role != Role::Linear {
                continue;
            }
            total += p.numel();
            active += match &self.linear_state[i] {
                Some(LinearState::Block { active: true, .. }) => p.numel(),
                Some(LinearState::Columns { cols, .. }) => p.dims().0 * cols.len(),
                Some(LinearState::RandK { idx, .. }) => idx.len(),
                Some(LinearState::Projected { proj, .. }) => {
                    // Rank-r subspace of a (rows×cols) matrix ~ r/min_dim.
                    let (rows, cols) = p.dims();
                    proj.rank() * rows.max(cols)
                }
                _ => 0,
            };
        }
        if total == 0 {
            0.0
        } else {
            active as f32 / total as f32
        }
    }
}

impl Optimizer for Frugal {
    fn name(&self) -> String {
        format!("frugal(rho={},{:?})", self.cfg.rho, self.cfg.projection)
    }

    fn step(&mut self, params: &mut [f32], grads: &[f32], lr: f32) {
        assert_eq!(params.len(), grads.len());
        if self.step_count % self.cfg.update_freq == 0 {
            self.reselect(grads);
        }
        self.step_count += 1;
        let lr_free = lr * self.cfg.lr_free_mult;

        for i in 0..self.layout.params.len() {
            let p = self.layout.params[i].clone();
            let range = p.offset..p.offset + p.numel();
            let g = &grads[range.clone()];

            if self.cfg.frozen_roles.contains(&p.role) {
                continue;
            }

            if p.role != Role::Linear {
                if let Some(state) = self.role_state[i].as_mut() {
                    apply_full(
                        state,
                        &self.cfg.state_full,
                        &mut params[range],
                        g,
                        lr,
                        &mut self.scratch,
                    );
                } else {
                    // Non-Linear role demoted to the state-free set
                    // (Table 4 machinery).
                    self.state_free_apply(&mut params[range], g, lr_free);
                }
                continue;
            }

            // Linear parameter: route through the projection state.
            let mut lstate = self.linear_state[i].take();
            match lstate.as_mut() {
                Some(LinearState::Block { active, state }) => {
                    if *active {
                        apply_full(
                            state.as_mut().unwrap(),
                            &self.cfg.state_full,
                            &mut params[range],
                            g,
                            lr,
                            &mut self.scratch,
                        );
                    } else {
                        self.state_free_apply(&mut params[range], g, lr_free);
                    }
                }
                Some(LinearState::Columns { cols, pos, state }) => {
                    let (rows, ncols) = p.dims();
                    let k = cols.len();
                    // Gather active-column grads.
                    self.scratch.clear();
                    self.scratch.resize(rows * k, 0.0);
                    for r in 0..rows {
                        for (j, &c) in cols.iter().enumerate() {
                            self.scratch[r * k + j] = g[r * ncols + c];
                        }
                    }
                    self.scratch2.clear();
                    self.scratch2.resize(rows * k, 0.0);
                    state.update_into(&self.cfg.state_full, &self.scratch, &mut self.scratch2);
                    let prm = &mut params[range];
                    for r in 0..rows {
                        for c in 0..ncols {
                            let lane = r * ncols + c;
                            if pos[c] >= 0 {
                                prm[lane] -= lr * self.scratch2[r * k + pos[c] as usize];
                            } else {
                                match self.cfg.state_free {
                                    StateFreeKind::SignSgd => {
                                        if g[lane] > 0.0 {
                                            prm[lane] -= lr_free;
                                        } else if g[lane] < 0.0 {
                                            prm[lane] += lr_free;
                                        }
                                    }
                                    StateFreeKind::Sgd => prm[lane] -= lr_free * g[lane],
                                    StateFreeKind::Frozen => {}
                                }
                            }
                        }
                    }
                }
                Some(LinearState::RandK { idx, member, state }) => {
                    let k = idx.len();
                    self.scratch.clear();
                    self.scratch.resize(k, 0.0);
                    for (j, &e) in idx.iter().enumerate() {
                        self.scratch[j] = g[e];
                    }
                    self.scratch2.clear();
                    self.scratch2.resize(k, 0.0);
                    state.update_into(&self.cfg.state_full, &self.scratch, &mut self.scratch2);
                    let prm = &mut params[range];
                    for lane in 0..prm.len() {
                        if member[lane] >= 0 {
                            prm[lane] -= lr * self.scratch2[member[lane] as usize];
                        } else {
                            match self.cfg.state_free {
                                StateFreeKind::SignSgd => {
                                    if g[lane] > 0.0 {
                                        prm[lane] -= lr_free;
                                    } else if g[lane] < 0.0 {
                                        prm[lane] += lr_free;
                                    }
                                }
                                StateFreeKind::Sgd => prm[lane] -= lr_free * g[lane],
                                StateFreeKind::Frozen => {}
                            }
                        }
                    }
                }
                Some(LinearState::Projected { proj, state }) => {
                    let (rows, cols) = p.dims();
                    let gm = Matrix::from_vec(rows, cols, g.to_vec());
                    let low = proj.down(&gm);
                    self.scratch2.clear();
                    self.scratch2.resize(low.data.len(), 0.0);
                    state.update_into(&self.cfg.state_full, &low.data, &mut self.scratch2);
                    let low_upd =
                        Matrix::from_vec(low.rows, low.cols, self.scratch2.clone());
                    let full_upd = proj.up(&low_upd);
                    // Residual g - P P^T g for the state-free branch.
                    let back = proj.up(&low);
                    let prm = &mut params[range];
                    for lane in 0..prm.len() {
                        prm[lane] -= lr * full_upd.data[lane];
                    }
                    let resid: Vec<f32> =
                        g.iter().zip(&back.data).map(|(a, b)| a - b).collect();
                    self.state_free_apply(prm, &resid, lr_free);
                }
                None => unreachable!("linear param without state after reselect"),
            }
            self.linear_state[i] = lstate;
        }
    }

    fn state_floats(&self) -> usize {
        let role: usize = self.role_state.iter().flatten().map(|s| s.floats()).sum();
        let lin: usize = self.linear_state.iter().flatten().map(|s| s.floats()).sum();
        role + lin
    }
}

/// Apply the state-full rule to a full (contiguous) region.
fn apply_full(
    state: &mut FullState,
    kind: &StateFullKind,
    params: &mut [f32],
    grads: &[f32],
    lr: f32,
    scratch: &mut Vec<f32>,
) {
    // AdamW fast path: one fused chunked pass, no scratch sweep — the
    // exact per-lane math of update_into + `p -= lr·out` (FRUGAL's
    // state-full rule never applied weight decay through this route, so
    // the fused form must not either).
    if let (FullState::Adam(st), StateFullKind::AdamW(cfg)) = (&mut *state, kind) {
        st.apply_no_decay(params, grads, lr, cfg);
        return;
    }
    // Other rules (Lion/SGDM): two-pass via scratch. update_into
    // overwrites every element, so sizing without the historical
    // zero-fill memset changes no value.
    if scratch.len() != params.len() {
        scratch.clear();
        scratch.resize(params.len(), 0.0);
    }
    state.update_into(kind, grads, scratch);
    for (p, &u) in params.iter_mut().zip(scratch.iter()) {
        *p -= lr * u;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layout() -> Layout {
        Layout::synthetic(32, 8, 20, 2)
    }

    fn grads_like(layout: &Layout, seed: u64) -> Vec<f32> {
        let mut rng = Prng::seed_from_u64(seed);
        let mut g = vec![0.0f32; layout.padded_size];
        for v in g[..layout.flat_size].iter_mut() {
            *v = crate::tensor::matrix::normal_sample(&mut rng) * 0.1;
        }
        g
    }

    #[test]
    fn padding_lanes_never_move() {
        let l = layout();
        let mut opt = Frugal::new(l.clone(), FrugalCfg::default());
        let mut p = vec![0.5f32; l.padded_size];
        let g = grads_like(&l, 0);
        opt.step(&mut p, &g, 1e-2);
        for lane in l.flat_size..l.padded_size {
            assert_eq!(p[lane], 0.5);
        }
    }

    #[test]
    fn rho_zero_blockwise_trains_everything_state_free() {
        let l = layout();
        let cfg = FrugalCfg { rho: 0.0, ..Default::default() };
        let mut opt = Frugal::new(l.clone(), cfg);
        let mut p = vec![0.0f32; l.padded_size];
        let g = grads_like(&l, 1);
        opt.step(&mut p, &g, 1e-2);
        // All linear lanes moved by exactly ±lr_free (signSGD).
        for info in l.linears() {
            for lane in info.offset..info.offset + info.numel() {
                if g[lane] != 0.0 {
                    assert!((p[lane].abs() - 1e-2).abs() < 1e-6, "lane {lane}");
                }
            }
        }
        // State floats = only the role params (embed/norm/output Adam).
        let role_numel: usize = l
            .params
            .iter()
            .filter(|p| p.role != Role::Linear)
            .map(|p| p.numel())
            .sum();
        assert_eq!(opt.state_floats(), 2 * role_numel);
    }

    #[test]
    fn rho_one_blockwise_is_full_adam() {
        let l = layout();
        let cfg = FrugalCfg { rho: 1.0, ..Default::default() };
        let mut opt = Frugal::new(l.clone(), cfg);
        let mut p = vec![0.0f32; l.padded_size];
        let g = grads_like(&l, 2);
        opt.step(&mut p, &g, 1e-3);
        // Compare against full AdamW on the real lanes.
        let mut p2 = vec![0.0f32; l.padded_size];
        let mut adam = super::super::AdamW::new(l.padded_size, AdamCfg::default());
        adam.step(&mut p2, &g, 1e-3);
        for lane in 0..l.flat_size {
            assert!((p[lane] - p2[lane]).abs() < 1e-6, "lane {lane}");
        }
    }

    #[test]
    fn realized_rho_tracks_target_blockwise() {
        let l = Layout::synthetic(64, 16, 40, 4);
        for rho in [0.0f32, 0.25, 0.5, 1.0] {
            let cfg = FrugalCfg { rho, ..Default::default() };
            let mut opt = Frugal::new(l.clone(), cfg);
            let g = grads_like(&l, 3);
            let mut p = vec![0.0f32; l.padded_size];
            opt.step(&mut p, &g, 1e-3);
            let realized = opt.realized_rho();
            // Blockwise granularity: within one block of the target.
            assert!(
                (realized - rho).abs() < 0.25,
                "rho={rho} realized={realized}"
            );
        }
    }

    #[test]
    fn columnwise_partition_is_exact() {
        let l = layout();
        let cfg = FrugalCfg {
            rho: 0.5,
            projection: ProjectionKind::Columnwise,
            ..Default::default()
        };
        let mut opt = Frugal::new(l.clone(), cfg);
        let g = grads_like(&l, 4);
        let mut p = vec![0.0f32; l.padded_size];
        opt.step(&mut p, &g, 1e-3);
        let realized = opt.realized_rho();
        assert!((realized - 0.5).abs() < 0.05, "realized={realized}");
    }

    #[test]
    fn randk_state_size_matches_rho() {
        let l = layout();
        let cfg = FrugalCfg {
            rho: 0.125,
            projection: ProjectionKind::RandK,
            ..Default::default()
        };
        let mut opt = Frugal::new(l.clone(), cfg);
        let g = grads_like(&l, 5);
        let mut p = vec![0.0f32; l.padded_size];
        opt.step(&mut p, &g, 1e-3);
        let lin_total = l.linear_numel();
        let role_total: usize =
            l.params.iter().filter(|p| p.role != Role::Linear).map(|p| p.numel()).sum();
        let expect = 2.0 * role_total as f32 + 2.0 * 0.125 * lin_total as f32;
        let got = opt.state_floats() as f32;
        assert!(
            (got - expect).abs() / expect < 0.05,
            "state={got} expect~{expect}"
        );
    }

    #[test]
    fn subspace_changes_across_rounds() {
        let l = layout();
        let cfg = FrugalCfg { update_freq: 1, rho: 0.3, seed: 9, ..Default::default() };
        let mut opt = Frugal::new(l.clone(), cfg);
        let g = grads_like(&l, 6);
        let mut p = vec![0.0f32; l.padded_size];
        let active_set = |o: &Frugal| -> Vec<bool> {
            o.linear_state
                .iter()
                .map(|s| matches!(s, Some(LinearState::Block { active: true, .. })))
                .collect()
        };
        opt.step(&mut p, &g, 1e-3);
        let a1 = active_set(&opt);
        let mut changed = false;
        for _ in 0..10 {
            opt.step(&mut p, &g, 1e-3);
            if active_set(&opt) != a1 {
                changed = true;
                break;
            }
        }
        assert!(changed, "active blocks never changed with T=1");
    }

    #[test]
    fn frozen_roles_do_not_move() {
        let l = layout();
        let cfg = FrugalCfg { frozen_roles: vec![Role::Embed], ..Default::default() };
        let mut opt = Frugal::new(l.clone(), cfg);
        let g = grads_like(&l, 7);
        let mut p = vec![0.1f32; l.padded_size];
        opt.step(&mut p, &g, 1e-2);
        let emb = l.params.iter().find(|p| p.role == Role::Embed).unwrap();
        for lane in emb.offset..emb.offset + emb.numel() {
            assert_eq!(p[lane], 0.1);
        }
    }

    #[test]
    fn svd_projection_runs_and_reduces_quadratic() {
        let l = layout();
        let cfg = FrugalCfg {
            projection: ProjectionKind::Svd,
            rho: 0.5,
            update_freq: 5,
            ..Default::default()
        };
        let mut opt = Frugal::new(l.clone(), cfg);
        let mut p = grads_like(&l, 8); // start away from 0
        let mut loss_first = None;
        for _ in 0..30 {
            let g: Vec<f32> = p.clone(); // grad of 0.5||p||^2
            let loss: f32 = p.iter().map(|x| x * x).sum();
            loss_first.get_or_insert(loss);
            opt.step(&mut p, &g, 1e-2);
        }
        let loss_last: f32 = p.iter().map(|x| x * x).sum();
        assert!(loss_last < loss_first.unwrap());
    }

    #[test]
    fn frozen_state_free_matches_badam_shape() {
        // StateFreeKind::Frozen + blockwise = BAdam-style updates: inactive
        // blocks do not move at all.
        let l = layout();
        let cfg = FrugalCfg {
            rho: 0.3,
            state_free: StateFreeKind::Frozen,
            ..Default::default()
        };
        let mut opt = Frugal::new(l.clone(), cfg);
        let g = grads_like(&l, 10);
        let mut p = vec![0.25f32; l.padded_size];
        opt.step(&mut p, &g, 1e-3);
        let mut frozen_lanes = 0;
        for info in l.linears() {
            let moved = (info.offset..info.offset + info.numel())
                .any(|lane| p[lane] != 0.25);
            if !moved {
                frozen_lanes += info.numel();
            }
        }
        assert!(frozen_lanes > 0, "some blocks must be frozen at rho=0.3");
    }
}
