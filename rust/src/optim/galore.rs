//! GaLore (Zhao et al., 2024a) — gradient low-rank projection baseline.
//!
//! Every `T` steps the projector P of each Linear matrix is recomputed
//! from the SVD of the current gradient; Adam state lives in the rank-r
//! space; the low-rank update is lifted back and applied. The state-free
//! subspace is **discarded** (that is the gap FRUGAL fills).
//!
//! `StateHandling` reproduces the paper's §D analysis: the original GaLore
//! `Keep`s stale state across projector changes (harmful at small T,
//! Table 14); `Project` rotates momentum into the new subspace with
//! momentum-mass normalization (Fig. 3); `Reset` zeroes it.


use crate::util::Prng;

use super::adamw::{AdamCfg, AdamState};
use super::projection::MatrixProjector;
use super::{Layout, Optimizer, Role};
use crate::tensor::Matrix;

/// What happens to optimizer state when the projector changes (paper §D).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StateHandling {
    /// Original GaLore: keep stale state (wrong subspace).
    Keep,
    /// Rotate momentum into the new subspace, normalize by momentum mass,
    /// reset variance (Hao et al. 2024 Alg. 2 + paper §D normalization).
    Project,
    /// Zero the state.
    Reset,
}

#[derive(Clone, Debug)]
pub struct GaLoreCfg {
    /// rho = r / min_dim (the paper's density generalization, §A).
    pub rho: f32,
    pub update_freq: u64,
    pub adam: AdamCfg,
    /// Use a random semi-orthogonal projector instead of SVD (Table 1 row
    /// "Random / No").
    pub random_projection: bool,
    pub state_handling: StateHandling,
    /// GaLore's lifted-update scale factor (alpha in the original paper).
    pub scale: f32,
    pub seed: u64,
}

impl Default for GaLoreCfg {
    fn default() -> Self {
        GaLoreCfg {
            rho: 0.25,
            update_freq: 200,
            adam: AdamCfg::default(),
            random_projection: false,
            state_handling: StateHandling::Keep,
            scale: 1.0,
            seed: 0,
        }
    }
}

struct ProjState {
    proj: MatrixProjector,
    adam: AdamState,
}

/// GaLore over the flat vector; non-Linear roles get full Adam (paper
/// §A.1: Embeddings/RMSNorms/Output always AdamW).
pub struct GaLore {
    pub cfg: GaLoreCfg,
    layout: Layout,
    lin: Vec<Option<ProjState>>,
    role_state: Vec<Option<AdamState>>,
    step_count: u64,
    rng: Prng,
    scratch: Vec<f32>,
}

impl GaLore {
    pub fn new(layout: Layout, cfg: GaLoreCfg) -> Self {
        let n = layout.params.len();
        let rng = Prng::seed_from_u64(cfg.seed);
        let mut role_state: Vec<Option<AdamState>> = (0..n).map(|_| None).collect();
        for (i, p) in layout.params.iter().enumerate() {
            if p.role != Role::Linear {
                role_state[i] = Some(AdamState::new(p.numel()));
            }
        }
        GaLore {
            cfg,
            layout,
            lin: (0..n).map(|_| None).collect(),
            role_state,
            step_count: 0,
            rng,
            scratch: Vec::new(),
        }
    }

    fn rank_for(&self, rows: usize, cols: usize) -> usize {
        ((self.cfg.rho * rows.min(cols) as f32).round() as usize).max(1)
    }

    fn refresh_projector(&mut self, i: usize, g: &Matrix) {
        let r = self.rank_for(g.rows, g.cols);
        let new_proj = if self.cfg.random_projection {
            MatrixProjector::random(g.rows, g.cols, r, &mut self.rng)
        } else {
            MatrixProjector::from_svd(g, r)
        };
        let state_n = match new_proj.side {
            super::projection::Side::Left => new_proj.rank() * g.cols,
            super::projection::Side::Right => g.rows * new_proj.rank(),
        };
        let old = self.lin[i].take();
        let mut adam = AdamState::new(state_n);
        match (old, self.cfg.state_handling) {
            (Some(mut old_state), StateHandling::Keep) => {
                // Keep stale buffers verbatim (sizes match: rank is fixed).
                if old_state.adam.m.len() == state_n {
                    std::mem::swap(&mut adam, &mut old_state.adam);
                }
            }
            (Some(old_state), StateHandling::Project) => {
                if old_state.proj.side == new_proj.side {
                    // m_new = R m_old, R = P_new^T P_old, then renormalize
                    // to preserve momentum mass (paper §D / Fig. 3).
                    let rot = new_proj.rotation_from(&old_state.proj);
                    let (mr, mc) = match new_proj.side {
                        super::projection::Side::Left => {
                            (old_state.proj.rank(), g.cols)
                        }
                        super::projection::Side::Right => {
                            (g.rows, old_state.proj.rank())
                        }
                    };
                    let old_m = Matrix::from_vec(mr, mc, old_state.adam.m.clone());
                    let new_m = match new_proj.side {
                        super::projection::Side::Left => rot.matmul(&old_m),
                        super::projection::Side::Right => old_m.matmul_t(&rot),
                    };
                    let old_norm = crate::tensor::norm(&old_state.adam.m);
                    let new_norm = crate::tensor::norm(&new_m.data);
                    let gain = if new_norm > 1e-12 { old_norm / new_norm } else { 0.0 };
                    adam.m.copy_from_slice(&new_m.data);
                    crate::tensor::scale(&mut adam.m, gain);
                    adam.t = old_state.adam.t; // momentum history continues
                }
            }
            _ => {} // Reset or first round: fresh zero state
        }
        self.lin[i] = Some(ProjState { proj: new_proj, adam });
    }
}

impl Optimizer for GaLore {
    fn name(&self) -> String {
        let kind = if self.cfg.random_projection { "random" } else { "svd" };
        format!("galore({kind},rho={},{:?})", self.cfg.rho, self.cfg.state_handling)
    }

    fn step(&mut self, params: &mut [f32], grads: &[f32], lr: f32) {
        let refresh = self.step_count % self.cfg.update_freq == 0;
        self.step_count += 1;
        for i in 0..self.layout.params.len() {
            let p = self.layout.params[i].clone();
            let range = p.offset..p.offset + p.numel();
            let g = &grads[range.clone()];
            if p.role != Role::Linear {
                let st = self.role_state[i].as_mut().unwrap();
                st.apply(&mut params[range], g, lr, &self.cfg.adam.clone());
                continue;
            }
            let (rows, cols) = p.dims();
            let gm = Matrix::from_vec(rows, cols, g.to_vec());
            if refresh || self.lin[i].is_none() {
                self.refresh_projector(i, &gm);
            }
            let adam_cfg = self.cfg.adam;
            let scale = self.cfg.scale;
            let st = self.lin[i].as_mut().unwrap();
            let low = st.proj.down(&gm);
            self.scratch.clear();
            self.scratch.resize(low.data.len(), 0.0);
            st.adam.update_into(&low.data, &adam_cfg, &mut self.scratch);
            let low_upd = Matrix::from_vec(low.rows, low.cols, self.scratch.clone());
            let full_upd = st.proj.up(&low_upd);
            let prm = &mut params[range];
            for lane in 0..prm.len() {
                prm[lane] -= lr * scale * full_upd.data[lane];
            }
        }
    }

    fn state_floats(&self) -> usize {
        let role: usize = self.role_state.iter().flatten().map(|s| s.floats()).sum();
        let lin: usize = self
            .lin
            .iter()
            .flatten()
            .map(|s| s.adam.floats() + s.proj.floats())
            .sum();
        role + lin
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layout() -> Layout {
        Layout::synthetic(32, 8, 20, 2)
    }

    fn grads(l: &Layout, seed: u64) -> Vec<f32> {
        let mut rng = Prng::seed_from_u64(seed);
        let mut g = vec![0.0f32; l.padded_size];
        for v in g[..l.flat_size].iter_mut() {
            *v = crate::tensor::matrix::normal_sample(&mut rng) * 0.1;
        }
        g
    }

    #[test]
    fn updates_are_low_rank() {
        let l = layout();
        let mut opt = GaLore::new(l.clone(), GaLoreCfg { rho: 0.25, ..Default::default() });
        let g = grads(&l, 0);
        let mut p = vec![0.0f32; l.padded_size];
        opt.step(&mut p, &g, 1e-3);
        // The update of each linear matrix has rank <= r.
        for info in l.linears() {
            let (rows, cols) = info.dims();
            let upd = Matrix::from_vec(
                rows,
                cols,
                p[info.offset..info.offset + info.numel()].to_vec(),
            );
            let s = crate::linalg::svd(&upd).s;
            let r = ((0.25 * rows.min(cols) as f32).round() as usize).max(1);
            for &sv in &s[r..] {
                assert!(sv < 1e-4 * s[0].max(1e-9), "rank exceeded: {s:?}");
            }
        }
    }

    #[test]
    fn state_is_low_rank_sized() {
        let l = layout();
        let opt_full = super::super::AdamW::new(l.padded_size, AdamCfg::default());
        let mut opt = GaLore::new(l.clone(), GaLoreCfg { rho: 0.25, ..Default::default() });
        let g = grads(&l, 1);
        let mut p = vec![0.0f32; l.padded_size];
        opt.step(&mut p, &g, 1e-3);
        assert!(opt.state_floats() < opt_full.state_floats());
    }

    #[test]
    fn keep_vs_reset_differ_at_small_t() {
        // §D: with frequent projector updates the three state handlings
        // produce different trajectories.
        let l = layout();
        let mk = |handling| {
            GaLore::new(
                l.clone(),
                GaLoreCfg { update_freq: 2, state_handling: handling, ..Default::default() },
            )
        };
        let mut keep = mk(StateHandling::Keep);
        let mut reset = mk(StateHandling::Reset);
        let mut pk = vec![0.0f32; l.padded_size];
        let mut pr = pk.clone();
        for s in 0..8 {
            let g = grads(&l, 100 + s);
            keep.step(&mut pk, &g, 1e-3);
            reset.step(&mut pr, &g, 1e-3);
        }
        assert_ne!(pk, pr);
    }

    #[test]
    fn random_projection_variant_runs() {
        let l = layout();
        let mut opt = GaLore::new(
            l.clone(),
            GaLoreCfg { random_projection: true, ..Default::default() },
        );
        let g = grads(&l, 2);
        let mut p = vec![0.0f32; l.padded_size];
        opt.step(&mut p, &g, 1e-3);
        assert!(p.iter().any(|&x| x != 0.0));
    }

    #[test]
    fn converges_on_quadratic_matrix_problem() {
        // min 0.5||W||^2 over one linear param — GaLore with projection
        // should still descend (it sees the full gradient each reselect).
        let l = layout();
        let mut opt = GaLore::new(
            l.clone(),
            GaLoreCfg { update_freq: 5, rho: 0.5, ..Default::default() },
        );
        let mut p = grads(&l, 3);
        let n0: f32 = p.iter().map(|x| x * x).sum();
        for _ in 0..50 {
            let g = p.clone();
            opt.step(&mut p, &g, 1e-2);
        }
        let n1: f32 = p.iter().map(|x| x * x).sum();
        assert!(n1 < n0);
    }
}
