//! LDAdam (Robert et al., 2024) — concurrent low-dim Adam (paper §B.1).
//!
//! Per-step low-rank updates with (a) the projector refreshed EVERY step
//! by cheap block power iteration instead of SVD, (b) projection-aware
//! state: momentum is rotated into the new subspace each refresh, and
//! (c) an **error-feedback buffer** that accumulates the discarded
//! residual and re-injects it into the next gradient — so information is
//! preserved even though each individual step is low-rank.
//!
//! Simplification (documented per DESIGN.md): the second moment is kept,
//! not rotated (rotating v exactly requires their generalized-error
//! scheme); with per-step refreshes the subspace drifts slowly, making the
//! approximation mild.

use super::adamw::{AdamCfg, AdamState};
use super::projection::{MatrixProjector, Side};
use super::{Layout, Optimizer, Role};
use crate::linalg::power_iteration;
use crate::tensor::Matrix;

#[derive(Clone, Debug)]
pub struct LdAdamCfg {
    pub rho: f32,
    pub adam: AdamCfg,
    /// Power-iteration steps per refresh (1 in the original).
    pub power_iters: usize,
}

impl Default for LdAdamCfg {
    fn default() -> Self {
        LdAdamCfg { rho: 0.25, adam: AdamCfg::default(), power_iters: 1 }
    }
}

struct LdState {
    proj: MatrixProjector,
    adam: AdamState,
    /// Error-feedback buffer (full-size): residual not yet applied.
    error: Vec<f32>,
}

pub struct LdAdam {
    pub cfg: LdAdamCfg,
    layout: Layout,
    lin: Vec<Option<LdState>>,
    role_state: Vec<Option<AdamState>>,
    scratch: Vec<f32>,
}

impl LdAdam {
    pub fn new(layout: Layout, cfg: LdAdamCfg) -> Self {
        let n = layout.params.len();
        let mut role_state: Vec<Option<AdamState>> = (0..n).map(|_| None).collect();
        for (i, p) in layout.params.iter().enumerate() {
            if p.role != Role::Linear {
                role_state[i] = Some(AdamState::new(p.numel()));
            }
        }
        LdAdam { cfg, layout, lin: (0..n).map(|_| None).collect(), role_state,
                 scratch: Vec::new() }
    }
}

impl Optimizer for LdAdam {
    fn name(&self) -> String {
        format!("ldadam(rho={})", self.cfg.rho)
    }

    fn step(&mut self, params: &mut [f32], grads: &[f32], lr: f32) {
        let adam_cfg = self.cfg.adam;
        for i in 0..self.layout.params.len() {
            let p = self.layout.params[i].clone();
            let range = p.offset..p.offset + p.numel();
            let g = &grads[range.clone()];
            if p.role != Role::Linear {
                self.role_state[i].as_mut().unwrap().apply(&mut params[range], g, lr, &adam_cfg);
                continue;
            }
            let (rows, cols) = p.dims();
            let r = ((self.cfg.rho * rows.min(cols) as f32).round() as usize).max(1);

            // Error feedback: work on G + E.
            let mut gm = Matrix::from_vec(rows, cols, g.to_vec());
            if let Some(st) = self.lin[i].as_ref() {
                for (x, e) in gm.data.iter_mut().zip(&st.error) {
                    *x += e;
                }
            }

            // Refresh projector by power iteration from the previous basis
            // (first round: SVD bootstrap), then rotate momentum.
            let new_proj = match self.lin[i].as_ref() {
                None => MatrixProjector::from_svd(&gm, r),
                Some(st) => {
                    let work = if st.proj.side == Side::Left { gm.clone() } else { gm.transpose() };
                    let q = power_iteration(&work, &st.proj.p, self.cfg.power_iters);
                    MatrixProjector { p: q, side: st.proj.side }
                }
            };
            let state_n = match new_proj.side {
                Side::Left => new_proj.rank() * cols,
                Side::Right => rows * new_proj.rank(),
            };
            let mut st = match self.lin[i].take() {
                Some(mut old) if old.adam.m.len() == state_n => {
                    // Rotate momentum: m_new = R m_old (projection-aware).
                    let rot = new_proj.rotation_from(&old.proj);
                    let (mr, mc) = match new_proj.side {
                        Side::Left => (old.proj.rank(), cols),
                        Side::Right => (rows, old.proj.rank()),
                    };
                    let m_old = Matrix::from_vec(mr, mc, old.adam.m.clone());
                    let m_new = match new_proj.side {
                        Side::Left => rot.matmul(&m_old),
                        Side::Right => m_old.matmul_t(&rot),
                    };
                    old.adam.m.copy_from_slice(&m_new.data);
                    LdState { proj: new_proj, adam: old.adam, error: old.error }
                }
                _ => LdState {
                    proj: new_proj,
                    adam: AdamState::new(state_n),
                    error: vec![0.0; rows * cols],
                },
            };

            // Low-rank Adam step.
            let low = st.proj.down(&gm);
            self.scratch.clear();
            self.scratch.resize(low.data.len(), 0.0);
            st.adam.update_into(&low.data, &adam_cfg, &mut self.scratch);
            let low_upd = Matrix::from_vec(low.rows, low.cols, self.scratch.clone());
            let full_upd = st.proj.up(&low_upd);

            // Error feedback: store what the low-rank step discarded.
            let back = st.proj.up(&low);
            for lane in 0..st.error.len() {
                st.error[lane] = gm.data[lane] - back.data[lane];
            }

            let prm = &mut params[range];
            for lane in 0..prm.len() {
                prm[lane] -= lr * full_upd.data[lane];
            }
            self.lin[i] = Some(st);
        }
    }

    fn state_floats(&self) -> usize {
        let role: usize = self.role_state.iter().flatten().map(|s| s.floats()).sum();
        let lin: usize = self
            .lin
            .iter()
            .flatten()
            .map(|s| s.adam.floats() + s.proj.floats() + s.error.len())
            .sum();
        role + lin
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    
    use crate::util::Prng;

    fn layout() -> Layout {
        Layout::synthetic(32, 8, 20, 2)
    }

    fn grads(l: &Layout, seed: u64) -> Vec<f32> {
        let mut rng = Prng::seed_from_u64(seed);
        let mut g = vec![0.0f32; l.padded_size];
        for v in g[..l.flat_size].iter_mut() {
            *v = crate::tensor::matrix::normal_sample(&mut rng) * 0.1;
        }
        g
    }

    #[test]
    fn error_feedback_accumulates_residual() {
        let l = layout();
        let mut opt = LdAdam::new(l.clone(), LdAdamCfg::default());
        let g = grads(&l, 0);
        let mut p = vec![0.0f32; l.padded_size];
        opt.step(&mut p, &g, 1e-3);
        let has_error = opt
            .lin
            .iter()
            .flatten()
            .any(|s| s.error.iter().any(|&e| e.abs() > 1e-8));
        assert!(has_error, "residual should be buffered");
    }

    #[test]
    fn single_step_is_low_rank() {
        let l = layout();
        let mut opt = LdAdam::new(l.clone(), LdAdamCfg { rho: 0.25, ..Default::default() });
        let g = grads(&l, 1);
        let mut p = vec![0.0f32; l.padded_size];
        opt.step(&mut p, &g, 1e-3);
        let info = l.linears().next().unwrap();
        let (rows, cols) = info.dims();
        let upd =
            Matrix::from_vec(rows, cols, p[info.offset..info.offset + info.numel()].to_vec());
        let s = crate::linalg::svd(&upd).s;
        let r = ((0.25 * rows.min(cols) as f32).round() as usize).max(1);
        for &sv in &s[r..] {
            assert!(sv < 1e-4 * s[0].max(1e-9), "update not low-rank: {s:?}");
        }
    }

    #[test]
    fn converges_on_quadratic() {
        let l = layout();
        let mut opt = LdAdam::new(l.clone(), LdAdamCfg::default());
        let mut p = grads(&l, 2);
        let n0: f32 = p.iter().map(|x| x * x).sum();
        for _ in 0..60 {
            let g = p.clone();
            opt.step(&mut p, &g, 1e-2);
        }
        let n1: f32 = p.iter().map(|x| x * x).sum();
        assert!(n1 < n0);
    }
}
