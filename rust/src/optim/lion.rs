//! Lion (Chen et al., 2024) — the alternative state-full rule of paper
//! Table 11: update = sign(β1 m + (1−β1) g); m ← β2 m + (1−β2) g.
//! One state buffer (half of Adam's).

use super::Optimizer;

#[derive(Clone, Copy, Debug)]
pub struct LionCfg {
    pub beta1: f32,
    pub beta2: f32,
    pub weight_decay: f32,
}

impl Default for LionCfg {
    fn default() -> Self {
        LionCfg { beta1: 0.9, beta2: 0.99, weight_decay: 0.0 }
    }
}

/// Reusable Lion state (shared with FRUGAL's Lion-as-state-full variant).
#[derive(Clone, Debug)]
pub struct LionState {
    pub m: Vec<f32>,
}

impl LionState {
    pub fn new(n: usize) -> Self {
        LionState { m: vec![0.0; n] }
    }

    pub fn reset(&mut self) {
        self.m.iter_mut().for_each(|x| *x = 0.0);
    }

    pub fn apply(&mut self, params: &mut [f32], grads: &[f32], lr: f32, cfg: &LionCfg) {
        for i in 0..params.len() {
            let interp = cfg.beta1 * self.m[i] + (1.0 - cfg.beta1) * grads[i];
            let dir = if interp > 0.0 {
                1.0
            } else if interp < 0.0 {
                -1.0
            } else {
                0.0
            };
            params[i] -= lr * (dir + cfg.weight_decay * params[i]);
            self.m[i] = cfg.beta2 * self.m[i] + (1.0 - cfg.beta2) * grads[i];
        }
    }

    pub fn floats(&self) -> usize {
        self.m.len()
    }
}

/// Full-rank Lion over the flat vector.
pub struct Lion {
    cfg: LionCfg,
    state: LionState,
}

impl Lion {
    pub fn new(n: usize, cfg: LionCfg) -> Self {
        Lion { cfg, state: LionState::new(n) }
    }
}

impl Optimizer for Lion {
    fn name(&self) -> String {
        "lion".into()
    }

    fn step(&mut self, params: &mut [f32], grads: &[f32], lr: f32) {
        self.state.apply(params, grads, lr, &self.cfg);
    }

    fn state_floats(&self) -> usize {
        self.state.floats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_step_is_sign_of_gradient() {
        let mut opt = Lion::new(2, LionCfg::default());
        let mut p = vec![0.0f32, 0.0];
        opt.step(&mut p, &[2.0, -0.1], 0.01);
        assert_eq!(p, vec![-0.01, 0.01]);
    }

    #[test]
    fn zero_everything_is_fixed_point() {
        let mut opt = Lion::new(2, LionCfg::default());
        let mut p = vec![1.0f32, -1.0];
        opt.step(&mut p, &[0.0, 0.0], 0.01);
        assert_eq!(p, vec![1.0, -1.0]);
    }

    #[test]
    fn state_is_single_buffer() {
        assert_eq!(Lion::new(64, LionCfg::default()).state_floats(), 64);
    }

    #[test]
    fn converges_on_quadratic() {
        let mut opt = Lion::new(2, LionCfg::default());
        let mut x = vec![4.0f32, -4.0];
        let mut lr = 0.1;
        for s in 0..800 {
            let g: Vec<f32> = x.clone();
            if s % 100 == 99 {
                lr *= 0.5; // sign methods need decaying lr to converge
            }
            opt.step(&mut x, &g, lr);
        }
        assert!(x.iter().all(|v| v.abs() < 0.2), "{x:?}");
    }
}
