//! LoRA (Hu et al., 2021) — the fine-tuning baseline of paper §7.
//!
//! For each targeted Linear matrix W (by name filter, e.g. wq/wv as in the
//! original RoBERTa setup), trains rank-r factors (B: m×r, A: r×n) with
//! AdamW and writes W = W₀ + (α/r)·B·A into the flat vector after every
//! step. W₀ stays frozen. Gradients come from the full-matrix gradient G
//! via the chain rule: ∂L/∂B = G Aᵀ, ∂L/∂A = Bᵀ G.
//!
//! Implemented as an [`Optimizer`] over the shared flat vector so the same
//! PJRT grad artifact drives it (the artifact differentiates w.r.t. the
//! *merged* W, which is exactly G).


use crate::util::Prng;

use super::adamw::{AdamCfg, AdamState};
use super::{Layout, Optimizer, Role};
use crate::tensor::Matrix;

#[derive(Clone, Debug)]
pub struct LoraCfg {
    pub rank: usize,
    /// LoRA scale α (update scale α/r).
    pub alpha: f32,
    /// Substrings selecting target matrices (paper §7.1: ["wq", "wv"];
    /// §7.2: q/k/v/up/down). Empty = all Linear matrices.
    pub targets: Vec<String>,
    pub adam: AdamCfg,
    /// Train non-Linear roles with Adam (the classification-head rule of
    /// Table 6: head always trained; embeddings frozen).
    pub train_roles: Vec<Role>,
    pub seed: u64,
}

impl Default for LoraCfg {
    fn default() -> Self {
        LoraCfg {
            rank: 8,
            alpha: 16.0,
            targets: vec!["wq".into(), "wv".into()],
            adam: AdamCfg::default(),
            train_roles: vec![Role::Output, Role::Norm],
            seed: 0,
        }
    }
}

struct Adapter {
    w0: Vec<f32>,
    a: Matrix,
    b: Matrix,
    adam_a: AdamState,
    adam_b: AdamState,
}

pub struct Lora {
    pub cfg: LoraCfg,
    layout: Layout,
    adapters: Vec<Option<Adapter>>,
    role_state: Vec<Option<AdamState>>,
    initialized: bool,
}

impl Lora {
    pub fn new(layout: Layout, cfg: LoraCfg) -> Self {
        let n = layout.params.len();
        let mut role_state: Vec<Option<AdamState>> = (0..n).map(|_| None).collect();
        for (i, p) in layout.params.iter().enumerate() {
            if p.role != Role::Linear && cfg.train_roles.contains(&p.role) {
                role_state[i] = Some(AdamState::new(p.numel()));
            }
        }
        Lora { cfg, layout, adapters: (0..n).map(|_| None).collect(), role_state,
               initialized: false }
    }

    fn is_target(&self, name: &str) -> bool {
        self.cfg.targets.is_empty()
            || self.cfg.targets.iter().any(|t| name.contains(t.as_str()))
    }

    /// Snapshot W₀ and initialize factors (A ~ N(0, 0.02), B = 0 — the
    /// standard LoRA init so the adapter starts as a no-op).
    fn init_from(&mut self, params: &[f32]) {
        let mut rng = Prng::seed_from_u64(self.cfg.seed);
        for i in 0..self.layout.params.len() {
            let p = &self.layout.params[i];
            if p.role != Role::Linear || !self.is_target(&p.name) {
                continue;
            }
            let (rows, cols) = p.dims();
            let r = self.cfg.rank.min(rows.min(cols));
            if r == 0 {
                continue;
            }
            let w0 = params[p.offset..p.offset + p.numel()].to_vec();
            self.adapters[i] = Some(Adapter {
                w0,
                a: Matrix::randn(r, cols, 0.02, &mut rng),
                b: Matrix::zeros(rows, r),
                adam_a: AdamState::new(r * cols),
                adam_b: AdamState::new(rows * r),
            });
        }
        self.initialized = true;
    }
}

impl Optimizer for Lora {
    fn name(&self) -> String {
        format!("lora(r={})", self.cfg.rank)
    }

    fn step(&mut self, params: &mut [f32], grads: &[f32], lr: f32) {
        if !self.initialized {
            self.init_from(params);
        }
        let adam = self.cfg.adam;
        let scale = self.cfg.alpha / self.cfg.rank.max(1) as f32;
        for i in 0..self.layout.params.len() {
            let p = self.layout.params[i].clone();
            let range = p.offset..p.offset + p.numel();
            let g = &grads[range.clone()];
            if p.role != Role::Linear {
                if let Some(st) = self.role_state[i].as_mut() {
                    st.apply(&mut params[range], g, lr, &adam);
                }
                continue;
            }
            let Some(ad) = self.adapters[i].as_mut() else {
                continue; // untargeted Linear stays frozen
            };
            let (rows, cols) = p.dims();
            let gm = Matrix::from_vec(rows, cols, g.to_vec());
            // dB = scale * G A^T ; dA = scale * B^T G.
            let db = gm.matmul_t(&ad.a).scaled(scale);
            let da = ad.b.t_matmul(&gm).scaled(scale);
            ad.adam_b.apply(&mut ad.b.data, &db.data, lr, &adam);
            ad.adam_a.apply(&mut ad.a.data, &da.data, lr, &adam);
            // Merge: W = W0 + scale * B A.
            let ba = ad.b.matmul(&ad.a);
            let prm = &mut params[range];
            for lane in 0..prm.len() {
                prm[lane] = ad.w0[lane] + scale * ba.data[lane];
            }
        }
    }

    fn state_floats(&self) -> usize {
        // Optimizer state only (W0 is frozen weights, not state — the paper
        // counts adapters' Adam buffers).
        let role: usize = self.role_state.iter().flatten().map(|s| s.floats()).sum();
        let ad: usize = self
            .adapters
            .iter()
            .flatten()
            .map(|a| a.adam_a.floats() + a.adam_b.floats())
            .sum();
        role + ad
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    

    fn layout() -> Layout {
        Layout::synthetic(32, 8, 20, 2)
    }

    fn grads(l: &Layout, seed: u64) -> Vec<f32> {
        let mut rng = Prng::seed_from_u64(seed);
        let mut g = vec![0.0f32; l.padded_size];
        for v in g[..l.flat_size].iter_mut() {
            *v = crate::tensor::matrix::normal_sample(&mut rng) * 0.1;
        }
        g
    }

    #[test]
    fn first_step_no_op_then_low_rank_delta() {
        let l = layout();
        let mut opt = Lora::new(l.clone(), LoraCfg { rank: 2, ..Default::default() });
        let p0 = grads(&l, 7); // arbitrary "pretrained" weights
        let mut p = p0.clone();
        let g = grads(&l, 8);
        opt.step(&mut p, &g, 1e-3);
        // Targeted matrices: delta = scale * B A has rank <= 2.
        for info in l.linears().filter(|p| p.name.contains("wq")) {
            let (rows, cols) = info.dims();
            let delta: Vec<f32> = (info.offset..info.offset + info.numel())
                .map(|x| p[x] - p0[x])
                .collect();
            let dm = Matrix::from_vec(rows, cols, delta);
            let s = crate::linalg::svd(&dm).s;
            for &sv in &s[2..] {
                assert!(sv < 1e-4 * s[0].max(1e-9), "delta not rank-2: {s:?}");
            }
        }
    }

    #[test]
    fn untargeted_linear_frozen() {
        let l = layout();
        let mut opt = Lora::new(l.clone(), LoraCfg::default()); // wq, wv only
        let p0 = grads(&l, 1);
        let mut p = p0.clone();
        let g = grads(&l, 2);
        for _ in 0..3 {
            opt.step(&mut p, &g, 1e-3);
        }
        for info in l.linears().filter(|p| p.name.contains("w_gate")) {
            for lane in info.offset..info.offset + info.numel() {
                assert_eq!(p[lane], p0[lane], "w_gate must stay frozen");
            }
        }
    }

    #[test]
    fn embeddings_frozen_head_trained() {
        let l = layout();
        let mut opt = Lora::new(l.clone(), LoraCfg::default());
        let p0 = grads(&l, 3);
        let mut p = p0.clone();
        let g = grads(&l, 4);
        opt.step(&mut p, &g, 1e-3);
        let emb = l.params.iter().find(|p| p.role == Role::Embed).unwrap();
        for lane in emb.offset..emb.offset + emb.numel() {
            assert_eq!(p[lane], p0[lane]);
        }
        let out = l.params.iter().find(|p| p.role == Role::Output).unwrap();
        let moved = (out.offset..out.offset + out.numel()).any(|x| p[x] != p0[x]);
        assert!(moved, "output head must train");
    }

    #[test]
    fn state_scales_with_rank_not_matrix() {
        let l = layout();
        let opt_r2 = {
            let mut o = Lora::new(l.clone(), LoraCfg { rank: 2, ..Default::default() });
            let mut p = grads(&l, 5);
            let g = grads(&l, 6);
            o.step(&mut p, &g, 1e-3);
            o.state_floats()
        };
        let opt_r4 = {
            let mut o = Lora::new(l.clone(), LoraCfg { rank: 4, ..Default::default() });
            let mut p = grads(&l, 5);
            let g = grads(&l, 6);
            o.step(&mut p, &g, 1e-3);
            o.state_floats()
        };
        assert!(opt_r4 > opt_r2);
        assert!(opt_r4 < l.linear_numel(), "lora state must be small");
    }
}
