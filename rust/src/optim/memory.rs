//! Analytic optimizer-memory accounting — paper §C and the parenthetical
//! GiB numbers of Tables 2 and 8.
//!
//! Evaluated at the paper's TRUE model sizes (LLaMA 60M–1B with the T5 32k
//! vocab), this module reproduces the printed numbers exactly:
//! AdamW 130M → 1.00G, GaLore ρ=0.25 → 0.54G, FRUGAL ρ=0.25 → 0.52G,
//! FRUGAL ρ=0 → 0.37G, etc. (see `paper_numbers_match` test).


/// LLaMA-family architecture dimensions (GaLore's experimental configs).
#[derive(Clone, Debug)]
pub struct ArchSpec {
    pub name: String,
    pub vocab: usize,
    pub h: usize,
    pub n_layers: usize,
    pub h_ff: usize,
}

impl ArchSpec {
    /// Scale names [`ArchSpec::paper_llama`] accepts.
    pub const PAPER_SCALES: [&'static str; 5] = ["60M", "130M", "350M", "1B", "3B"];

    /// The paper's model scales (vocab 32k via the T5 tokenizer, §A.1).
    /// Unknown names are a user-facing error (bad `--model`), not a bug,
    /// so this returns `Result` rather than panicking.
    pub fn paper_llama(name: &str) -> crate::Result<ArchSpec> {
        let (h, l, hff) = match name {
            "60M" => (512, 8, 1376),
            "130M" => (768, 12, 2048),
            "350M" => (1024, 24, 2736),
            "1B" => (2048, 24, 5461),
            "3B" => (2560, 32, 6848),
            _ => anyhow::bail!(
                "unknown paper config '{name}' (expected one of {})",
                Self::PAPER_SCALES.join(", ")
            ),
        };
        Ok(ArchSpec { name: name.into(), vocab: 32_000, h, n_layers: l, h_ff: hff })
    }

    /// Linear-layer parameter count P (paper §C): per layer 4·h² (QKVO)
    /// plus 3·h·h_ff (gate/up/down).
    pub fn linear_params(&self) -> u64 {
        self.n_layers as u64 * (4 * (self.h as u64) * (self.h as u64)
            + 3 * (self.h as u64) * (self.h_ff as u64))
    }

    /// Lanes in the state-full subspace at density ρ: all non-Linear
    /// parameters plus ρ of the Linear ones (§4).
    pub fn statefull_lanes(&self, rho: f64) -> u64 {
        self.non_linear_params() + (rho * self.linear_params() as f64).round() as u64
    }

    /// Lanes in the state-free complement (signSGD — the 1-bit group of
    /// the engine's split reduce-tree codec).
    pub fn statefree_lanes(&self, rho: f64) -> u64 {
        self.total_params() - self.statefull_lanes(rho)
    }

    /// Always-state-full parameters: embeddings + output + RMSNorms.
    pub fn non_linear_params(&self) -> u64 {
        let emb = (self.vocab as u64) * (self.h as u64);
        let norms = self.n_layers as u64 * 2 * self.h as u64 + self.h as u64;
        2 * emb + norms
    }

    pub fn total_params(&self) -> u64 {
        self.linear_params() + self.non_linear_params()
    }
}

/// Optimization method, for accounting purposes.
#[derive(Clone, Debug, PartialEq)]
pub enum Method {
    AdamW,
    /// GaLore with SVD semi-orthogonal P: 26/24 overhead factor (§C).
    GaLore { rho: f64 },
    /// BAdam / FRUGAL blockwise / columnwise / RandK: plain 2ρP.
    BAdam { rho: f64 },
    Frugal { rho: f64 },
    /// FRUGAL with a dense projection matrix (SVD/Random rows of Table 1).
    FrugalProjected { rho: f64 },
    SignSgd,
    Sgd,
    Sgdm,
    Lion,
    Adafactor,
    Lora { rank: usize, targets_per_layer: usize },
}

/// Bytes of optimizer state for `arch` under `method`, with
/// `bytes_per_float` (4 for f32 — the paper's mixed-precision setting).
pub fn optimizer_state_bytes(arch: &ArchSpec, method: &Method, bytes_per_float: u64) -> u64 {
    let p_lin = arch.linear_params();
    let p_nl = arch.non_linear_params();
    let floats: f64 = match method {
        Method::AdamW => 2.0 * (p_lin + p_nl) as f64,
        // Non-linear modules always carry full Adam state (paper §A.1).
        Method::GaLore { rho } => 2.0 * p_nl as f64 + (26.0 / 24.0) * 2.0 * rho * p_lin as f64,
        Method::BAdam { rho } | Method::Frugal { rho } => {
            2.0 * p_nl as f64 + 2.0 * rho * p_lin as f64
        }
        Method::FrugalProjected { rho } => {
            2.0 * p_nl as f64 + (26.0 / 24.0) * 2.0 * rho * p_lin as f64
        }
        Method::SignSgd | Method::Sgd => 0.0,
        Method::Sgdm => (p_lin + p_nl) as f64,
        Method::Lion => (p_lin + p_nl) as f64,
        // Adafactor: row+col accumulators per matrix.
        Method::Adafactor => {
            let per_layer = 4 * 2 * arch.h + 3 * (arch.h + arch.h_ff);
            (arch.n_layers * per_layer + 2 * (arch.vocab + arch.h)) as f64
        }
        // LoRA: Adam state for the adapters only (plus the head, counted in
        // p_nl-style by callers if needed). 2 states × r(m+n) per target.
        Method::Lora { rank, targets_per_layer } => {
            let per = 2 * rank * (arch.h + arch.h) * targets_per_layer;
            (arch.n_layers * per) as f64
        }
    };
    (floats * bytes_per_float as f64).round() as u64
}

/// Weights+gradients+optimizer bytes (paper Table 3 reports this total).
pub fn total_training_bytes(arch: &ArchSpec, method: &Method, bytes_per_float: u64) -> u64 {
    let wg = 2 * arch.total_params() * bytes_per_float;
    wg + optimizer_state_bytes(arch, method, bytes_per_float)
}

/// Format bytes the way the paper prints them: GiB with 2 decimals + "G".
pub fn fmt_gib(bytes: u64) -> String {
    format!("{:.2}G", bytes as f64 / (1u64 << 30) as f64)
}

/// One lane group's wire encoding on the engine's reduce tree — the
/// analytic counterpart of `engine::compress::Payload` for the `memory`
/// command's accounting.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WireCodec {
    /// Raw fp32 lanes.
    F32,
    /// 1-bit sign + one fp32 scale per `block` lanes (SignEf leaves).
    /// Signs ship as packed u64 words, so the payload rounds up to
    /// 8-byte granularity — the bytes the transport actually frames.
    Sign1 { block: u64 },
    /// 8-bit absmax + one fp32 scale per `block` lanes (BlockQ8).
    Q8 { block: u64 },
    /// 4-bit absmax, two lanes per byte + one fp32 scale per `block`
    /// lanes (BlockQ4).
    Q4 { block: u64 },
    /// Top-k magnitude sparsification (TopKEf): `k_permille`/1000 of
    /// the lanes survive, each shipped as a u32 index + f32 value.
    TopK { k_permille: u64 },
}

/// Bytes of fp32 block scales shipped alongside a compressed payload of
/// `lanes` lanes — the codec's per-message metadata overhead.
pub fn scale_overhead_bytes(lanes: u64, block: u64) -> u64 {
    4 * lanes.div_ceil(block.max(1))
}

/// Bytes one lane group occupies on the wire under `codec` (payload +
/// block scales).
pub fn lane_wire_bytes(lanes: u64, codec: WireCodec) -> u64 {
    match codec {
        WireCodec::F32 => 4 * lanes,
        // div_ceil(64) * 8, not div_ceil(8): the transport serializes
        // whole u64 sign words, so that is what the wire pays.
        WireCodec::Sign1 { block } => {
            lanes.div_ceil(64) * 8 + scale_overhead_bytes(lanes, block)
        }
        WireCodec::Q8 { block } => lanes + scale_overhead_bytes(lanes, block),
        WireCodec::Q4 { block } => lanes.div_ceil(2) + scale_overhead_bytes(lanes, block),
        WireCodec::TopK { k_permille } => {
            if lanes == 0 {
                return 0;
            }
            8 * (lanes * k_permille / 1000).clamp(1, lanes)
        }
    }
}

/// Analytic accounting of one split-compressed leaf message (the widest
/// reduce-tree hop): what `--compress split` saves on the wire and what
/// it costs in residual + scale state.
#[derive(Clone, Copy, Debug)]
pub struct SplitWireReport {
    /// fp32 baseline bytes for one full-gradient message.
    pub dense_bytes: u64,
    /// Encoded bytes: Q8 state-full lanes + 1-bit state-free lanes.
    pub wire_bytes: u64,
    /// Of which fp32 block scales (metadata overhead).
    pub scale_bytes: u64,
    /// Worker-side EF residual floats per micro-batch slot (fp32 over
    /// the state-free lanes).
    pub residual_floats: u64,
}

impl SplitWireReport {
    /// Compression factor vs fp32.
    pub fn reduction(&self) -> f64 {
        self.dense_bytes as f64 / self.wire_bytes as f64
    }
}

/// Bytes of one engine snapshot (the `ckpt` subsystem) at density `rho`:
/// the raw-f32 flat parameter vector, the u32 state-full lane ids (the
/// mask), both Adam moment arrays stored through `moments` —
/// [`WireCodec::F32`] models the `raw` checkpoint codec,
/// [`WireCodec::Q8`] the `BlockQ8` one — and, when the run's wire codec
/// carries error feedback (`sign-ef`/`split`), `ef_slots = grad_accum`
/// raw-f32 residual buffers over the state-free lanes (pass 0 for
/// `none`/`q8` wire modes). FRUGAL's point applies to snapshots too:
/// only the K state-full lanes carry moments, so the EF-less checkpoint
/// is a fraction of a dense-Adam blob (params + 2 full moments = 12
/// bytes/param) — but note the residual banks scale with `grad_accum ×
/// (1-rho)` and dominate split-mode snapshots at large global batches.
pub fn checkpoint_bytes(arch: &ArchSpec, rho: f64, moments: WireCodec, ef_slots: u64) -> u64 {
    let full = arch.statefull_lanes(rho);
    4 * arch.total_params()
        + 4 * full
        + 2 * lane_wire_bytes(full, moments)
        + ef_slots * 4 * arch.statefree_lanes(rho)
}

/// One epoch row of the scheduled-memory table: the analytic FRUGAL
/// optimizer-state footprint at that mask epoch's scheduled ρ.
#[derive(Clone, Debug)]
pub struct ScheduledStateRow {
    pub epoch: u64,
    pub rho: f64,
    pub state_bytes: u64,
}

/// Per-epoch FRUGAL optimizer-state bytes under a variable-ρ schedule —
/// the analytic "declining state footprint" of adaptive-density
/// training. Epoch 0 is the first mask epoch; a decaying schedule
/// yields a non-increasing column, and the peak (what must actually be
/// provisioned) is [`peak_scheduled_state_bytes`].
pub fn scheduled_state_table(
    arch: &ArchSpec,
    schedule: &crate::schedule::RhoSchedule,
    epochs: u64,
    bytes_per_float: u64,
) -> Vec<ScheduledStateRow> {
    (0..epochs.max(1))
        .map(|epoch| {
            let rho = schedule.rho_at(epoch);
            ScheduledStateRow {
                epoch,
                rho,
                state_bytes: optimizer_state_bytes(
                    arch,
                    &Method::Frugal { rho },
                    bytes_per_float,
                ),
            }
        })
        .collect()
}

/// The epoch-max of a [`scheduled_state_table`] — the provisioning peak
/// a variable-ρ run pays (for a decaying schedule: epoch 0's footprint;
/// every later epoch runs strictly lighter).
pub fn peak_scheduled_state_bytes(table: &[ScheduledStateRow]) -> u64 {
    table.iter().map(|r| r.state_bytes).max().unwrap_or(0)
}

/// [`SplitWireReport`] for `arch` at density `rho` with `block`-lane
/// scale blocks.
pub fn split_wire_report(arch: &ArchSpec, rho: f64, block: u64) -> SplitWireReport {
    let full = arch.statefull_lanes(rho);
    let free = arch.statefree_lanes(rho);
    SplitWireReport {
        dense_bytes: 4 * (full + free),
        wire_bytes: lane_wire_bytes(full, WireCodec::Q8 { block })
            + lane_wire_bytes(free, WireCodec::Sign1 { block }),
        scale_bytes: scale_overhead_bytes(full, block) + scale_overhead_bytes(free, block),
        residual_floats: free,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_param_counts() {
        // Total params must land near the advertised scale names.
        let close = |arch: &ArchSpec, m: f64| {
            let t = arch.total_params() as f64 / 1e6;
            assert!((t - m).abs() / m < 0.15, "{}: {}M vs {}M", arch.name, t, m);
        };
        close(&ArchSpec::paper_llama("60M").unwrap(), 58.0);
        close(&ArchSpec::paper_llama("130M").unwrap(), 134.0);
        close(&ArchSpec::paper_llama("350M").unwrap(), 368.0);
        close(&ArchSpec::paper_llama("1B").unwrap(), 1340.0);
    }

    /// The headline reproduction: Table 2's parenthetical memory numbers.
    #[test]
    fn paper_numbers_match() {
        let cases: &[(&str, Method, &str)] = &[
            ("60M", Method::AdamW, "0.43G"),
            ("130M", Method::AdamW, "1.00G"),
            ("350M", Method::AdamW, "2.74G"),
            ("1B", Method::AdamW, "9.98G"),
            ("60M", Method::GaLore { rho: 0.25 }, "0.30G"),
            ("130M", Method::GaLore { rho: 0.25 }, "0.54G"),
            ("350M", Method::GaLore { rho: 0.25 }, "1.10G"),
            ("1B", Method::GaLore { rho: 0.25 }, "3.41G"),
            ("60M", Method::Frugal { rho: 0.25 }, "0.29G"),
            ("130M", Method::Frugal { rho: 0.25 }, "0.52G"),
            ("350M", Method::Frugal { rho: 0.25 }, "1.05G"),
            ("1B", Method::Frugal { rho: 0.25 }, "3.23G"),
            ("60M", Method::Frugal { rho: 0.0 }, "0.24G"),
            ("130M", Method::Frugal { rho: 0.0 }, "0.37G"),
            ("350M", Method::Frugal { rho: 0.0 }, "0.49G"),
            ("1B", Method::Frugal { rho: 0.0 }, "0.98G"),
        ];
        for (scale, method, want) in cases {
            let arch = ArchSpec::paper_llama(scale).unwrap();
            let got = fmt_gib(optimizer_state_bytes(&arch, method, 4));
            // Allow 0.01–0.02G of rounding slack against the paper print.
            let gw: f64 = want.trim_end_matches('G').parse().unwrap();
            let gg: f64 = got.trim_end_matches('G').parse().unwrap();
            assert!(
                (gw - gg).abs() <= 0.03 + 0.01 * gw,
                "{scale} {method:?}: got {got}, paper says {want}"
            );
        }
    }

    #[test]
    fn unknown_scale_is_a_clean_error() {
        let err = ArchSpec::paper_llama("7B").unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("unknown paper config '7B'"), "{msg}");
        assert!(msg.contains("130M"), "should list valid scales: {msg}");
    }

    #[test]
    fn frugal_strictly_cheaper_than_galore_at_same_rho() {
        for scale in ["60M", "130M", "350M", "1B"] {
            let arch = ArchSpec::paper_llama(scale).unwrap();
            let f = optimizer_state_bytes(&arch, &Method::Frugal { rho: 0.25 }, 4);
            let g = optimizer_state_bytes(&arch, &Method::GaLore { rho: 0.25 }, 4);
            assert!(f < g, "{scale}: frugal {f} !< galore {g}");
        }
    }

    #[test]
    fn zero_state_methods() {
        let arch = ArchSpec::paper_llama("130M").unwrap();
        assert_eq!(optimizer_state_bytes(&arch, &Method::SignSgd, 4), 0);
        assert_eq!(optimizer_state_bytes(&arch, &Method::Sgd, 4), 0);
    }

    #[test]
    fn monotone_in_rho() {
        let arch = ArchSpec::paper_llama("130M").unwrap();
        let mut prev = 0;
        for rho in [0.0, 0.0625, 0.125, 0.25, 0.5, 1.0] {
            let b = optimizer_state_bytes(&arch, &Method::Frugal { rho }, 4);
            assert!(b >= prev);
            prev = b;
        }
        // rho=1 equals full AdamW.
        let full = optimizer_state_bytes(&arch, &Method::AdamW, 4);
        assert_eq!(prev, full);
    }

    #[test]
    fn adafactor_sublinear() {
        let arch = ArchSpec::paper_llama("130M").unwrap();
        let af = optimizer_state_bytes(&arch, &Method::Adafactor, 4);
        let adam = optimizer_state_bytes(&arch, &Method::AdamW, 4);
        assert!(af < adam / 10);
    }

    #[test]
    fn lane_wire_bytes_arithmetic() {
        // 1000 lanes, block 256 -> 4 scale floats.
        assert_eq!(scale_overhead_bytes(1000, 256), 16);
        assert_eq!(lane_wire_bytes(1000, WireCodec::F32), 4000);
        assert_eq!(lane_wire_bytes(1000, WireCodec::Q8 { block: 256 }), 1000 + 16);
        // 1000 signs occupy 16 serialized u64 words (128 bytes), not
        // div_ceil(1000/8) = 125 packed bytes — the metering must report
        // what the transport frames.
        assert_eq!(lane_wire_bytes(1000, WireCodec::Sign1 { block: 256 }), 128 + 16);
        assert_eq!(lane_wire_bytes(64, WireCodec::Sign1 { block: 256 }), 8 + 4);
        assert_eq!(lane_wire_bytes(65, WireCodec::Sign1 { block: 256 }), 16 + 4);
        assert_eq!(lane_wire_bytes(1000, WireCodec::Q4 { block: 256 }), 500 + 16);
        assert_eq!(lane_wire_bytes(1001, WireCodec::Q4 { block: 256 }), 501 + 16);
        // TopK: 5‰ of 1000 lanes = 5 survivors at 8B each; the floor is
        // one survivor.
        assert_eq!(lane_wire_bytes(1000, WireCodec::TopK { k_permille: 5 }), 40);
        assert_eq!(lane_wire_bytes(10, WireCodec::TopK { k_permille: 5 }), 8);
        assert_eq!(lane_wire_bytes(0, WireCodec::TopK { k_permille: 5 }), 0);
        // Degenerate block sizes clamp instead of dividing by zero.
        assert_eq!(scale_overhead_bytes(8, 0), 32);
    }

    #[test]
    fn statefull_statefree_partition_total_params() {
        let arch = ArchSpec::paper_llama("130M").unwrap();
        for rho in [0.0, 0.25, 1.0] {
            let full = arch.statefull_lanes(rho);
            let free = arch.statefree_lanes(rho);
            assert_eq!(full + free, arch.total_params(), "rho={rho}");
        }
        assert_eq!(arch.statefree_lanes(1.0), 0);
        assert_eq!(arch.statefull_lanes(0.0), arch.non_linear_params());
    }

    #[test]
    fn checkpoint_bytes_track_the_codec_and_beat_dense_adam() {
        let block = 256u64;
        for scale in ["60M", "130M", "350M", "1B"] {
            let arch = ArchSpec::paper_llama(scale).unwrap();
            let raw = checkpoint_bytes(&arch, 0.25, WireCodec::F32, 0);
            let q8 = checkpoint_bytes(&arch, 0.25, WireCodec::Q8 { block }, 0);
            let full = arch.statefull_lanes(0.25);
            // q8 drops ~3 of each moment float's 4 bytes (x2 moments),
            // minus the block-scale overhead.
            let saved = raw - q8;
            assert!(saved >= 5 * full, "{scale}: q8 only saved {saved}B over {full} lanes");
            assert!(q8 < raw);
            // q8 stays well under a dense-Adam snapshot (params + 2 full
            // f32 moments = 12 bytes/param) at every scale.
            let dense_adam = 12 * arch.total_params();
            assert!(10 * q8 < 7 * dense_adam, "{scale}: q8 ckpt {q8} vs dense {dense_adam}");
        }
        // rho monotonicity: more state-full lanes, bigger snapshot.
        let arch = ArchSpec::paper_llama("130M").unwrap();
        let mut prev = 0;
        for rho in [0.0, 0.25, 0.5, 1.0] {
            let b = checkpoint_bytes(&arch, rho, WireCodec::Q8 { block }, 0);
            assert!(b > prev);
            prev = b;
        }
        // EF residual accounting: each slot adds exactly 4 bytes per
        // state-free lane, and at rho=1 there are no free lanes to carry.
        let base = checkpoint_bytes(&arch, 0.25, WireCodec::Q8 { block }, 0);
        let with_ef = checkpoint_bytes(&arch, 0.25, WireCodec::Q8 { block }, 4);
        assert_eq!(with_ef - base, 16 * arch.statefree_lanes(0.25));
        assert_eq!(
            checkpoint_bytes(&arch, 1.0, WireCodec::Q8 { block }, 4),
            checkpoint_bytes(&arch, 1.0, WireCodec::Q8 { block }, 0)
        );
    }

    #[test]
    fn split_wire_report_shrinks_at_least_3x_at_paper_scales() {
        // The acceptance-criterion bound, checked analytically at every
        // paper scale: the split codec must beat 3x even with all
        // non-Linear lanes forced state-full (the worst case for it).
        for scale in ["60M", "130M", "350M", "1B", "3B"] {
            let arch = ArchSpec::paper_llama(scale).unwrap();
            let r = split_wire_report(&arch, 0.25, 256);
            assert!(
                r.reduction() >= 3.0,
                "{scale}: split reduction {:.2}x < 3x",
                r.reduction()
            );
            // Scale metadata stays a sliver of the wire bytes, and the
            // residual is bounded by the state-free lane count.
            assert!(r.scale_bytes * 20 < r.wire_bytes, "{scale}: scale overhead too big");
            assert_eq!(r.residual_floats, arch.statefree_lanes(0.25));
        }
    }

    #[test]
    fn scheduled_state_table_declines_with_rho_and_peaks_at_epoch_zero() {
        use crate::schedule::RhoSchedule;
        let arch = ArchSpec::paper_llama("130M").unwrap();
        let sched = RhoSchedule::parse("linear:0.5:0.0:8").unwrap();
        let table = scheduled_state_table(&arch, &sched, 10, 4);
        assert_eq!(table.len(), 10);
        // Declining footprint: non-increasing, strictly smaller by the
        // end (the whole point of annealing ρ).
        for w in table.windows(2) {
            assert!(w[1].state_bytes <= w[0].state_bytes, "footprint grew");
        }
        assert!(table[9].state_bytes < table[0].state_bytes);
        // Endpoints match the fixed-ρ analytic model exactly.
        assert_eq!(
            table[0].state_bytes,
            optimizer_state_bytes(&arch, &Method::Frugal { rho: 0.5 }, 4)
        );
        assert_eq!(
            table[9].state_bytes,
            optimizer_state_bytes(&arch, &Method::Frugal { rho: 0.0 }, 4)
        );
        // Peak = what must be provisioned = epoch 0 for a decay.
        assert_eq!(peak_scheduled_state_bytes(&table), table[0].state_bytes);
        // A constant schedule reproduces the scalar knob at every epoch.
        let flat = scheduled_state_table(&arch, &RhoSchedule::constant(0.25), 4, 4);
        for row in &flat {
            assert_eq!(
                row.state_bytes,
                optimizer_state_bytes(&arch, &Method::Frugal { rho: 0.25 }, 4)
            );
        }
    }

    #[test]
    fn table3_total_memory_shape() {
        // Table 3: pure-bf16 350M (2.1GB) ≈ mixed-precision 175M (2.0GB)
        // — i.e. halving the bytes roughly doubles the affordable size.
        let m350 = ArchSpec::paper_llama("350M").unwrap();
        let bf16 = total_training_bytes(&m350, &Method::AdamW, 2);
        let f32_ = total_training_bytes(&m350, &Method::AdamW, 4);
        assert!((f32_ as f64 / bf16 as f64 - 2.0).abs() < 0.01);
    }
}
