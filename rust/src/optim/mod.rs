//! The optimizer suite: FRUGAL and every baseline the paper compares.
//!
//! All optimizers operate on the **flat parameter vector** (the interchange
//! format with the PJRT artifacts) plus a [`Layout`] describing where each
//! named parameter lives and what module role it plays. Projection-based
//! methods (GaLore, BAdam, FRUGAL, Fira, LDAdam, AdaMeM) view the Linear
//! slices as matrices; everything else is elementwise.
//!
//! Memory honesty: each optimizer allocates state **only** for the lanes it
//! preconditions — `state_floats()` reports the real allocation and the
//! proptest suite checks it against the analytic model in [`memory`].

pub mod adafactor;
pub mod adamem;
pub mod adamw;
pub mod badam;
pub mod fira;
pub mod frugal;
pub mod galore;
pub mod ldadam;
pub mod lion;
pub mod lora;
pub mod memory;
pub mod projection;
pub mod sgd;

pub use adamw::{AdamCfg, AdamState, AdamW};
pub use frugal::{Frugal, FrugalCfg, ProjectionKind, StateFreeKind, StateFullKind};
pub use galore::{GaLore, GaLoreCfg, StateHandling};
pub use lora::{Lora, LoraCfg};


/// Module role — the classes the paper treats differently (§6.1, §A.1):
/// Embeddings, Norms and the Output layer default to the always-state-full
/// set; Linear layers are the projectable set.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Role {
    Embed,
    Norm,
    Linear,
    Output,
}

/// One named parameter tensor inside the flat vector.
#[derive(Clone, Debug)]
pub struct ParamInfo {
    pub name: String,
    pub role: Role,
    pub offset: usize,
    pub shape: Vec<usize>,
}

impl ParamInfo {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    /// Matrix view dims (rows, cols); vectors are (1, n).
    pub fn dims(&self) -> (usize, usize) {
        match self.shape.len() {
            2 => (self.shape[0], self.shape[1]),
            1 => (1, self.shape[0]),
            _ => (self.shape[0], self.numel() / self.shape[0]),
        }
    }

    /// Transformer layer index parsed from `layers.<i>.` names.
    pub fn layer(&self) -> Option<usize> {
        self.name.strip_prefix("layers.")?.split('.').next()?.parse().ok()
    }
}

/// The flat-vector layout: the Rust mirror of `artifacts/manifest.json`.
#[derive(Clone, Debug)]
pub struct Layout {
    pub params: Vec<ParamInfo>,
    pub flat_size: usize,
    pub padded_size: usize,
}

impl Layout {
    pub fn new(params: Vec<ParamInfo>, padded_size: usize) -> Self {
        let flat_size = params.iter().map(|p| p.numel()).sum();
        Layout { params, flat_size, padded_size }
    }

    /// Linear-role parameters (the projectable set).
    pub fn linears(&self) -> impl Iterator<Item = &ParamInfo> {
        self.params.iter().filter(|p| p.role == Role::Linear)
    }

    /// Number of transformer layers present.
    pub fn n_layers(&self) -> usize {
        self.params.iter().filter_map(|p| p.layer()).max().map_or(0, |l| l + 1)
    }

    /// Total Linear parameter count (the paper's `P`).
    pub fn linear_numel(&self) -> usize {
        self.linears().map(|p| p.numel()).sum()
    }

    /// Stable fingerprint of the model shape + split layout: parameter
    /// names, roles, offsets, shapes, and the flat/padded sizes.
    /// Checkpoint manifests persist it so a resume against a different
    /// model config is rejected with the real diagnosis *before* any
    /// lane-count check (FNV-1a over the canonical description — no
    /// external hasher, so `optim` stays dependency-free).
    pub fn fingerprint(&self) -> String {
        fn fnv(mut h: u64, bytes: &[u8]) -> u64 {
            for &b in bytes {
                h ^= u64::from(b);
                h = h.wrapping_mul(0x0000_0100_0000_01B3);
            }
            h
        }
        let mut h: u64 = 0xCBF2_9CE4_8422_2325;
        for p in &self.params {
            h = fnv(h, p.name.as_bytes());
            h = fnv(h, format!("{:?}", p.role).as_bytes());
            h = fnv(h, &(p.offset as u64).to_le_bytes());
            for &d in &p.shape {
                h = fnv(h, &(d as u64).to_le_bytes());
            }
            h = fnv(h, b";");
        }
        h = fnv(h, &(self.flat_size as u64).to_le_bytes());
        h = fnv(h, &(self.padded_size as u64).to_le_bytes());
        format!("{h:016x}-p{}-f{}-P{}", self.params.len(), self.flat_size, self.padded_size)
    }

    /// A tiny synthetic layout for tests/benches: `n_layers` layers of
    /// (d×d) attention-ish and (d×ff) MLP-ish matrices plus embed/norm/out.
    pub fn synthetic(vocab: usize, d: usize, ff: usize, n_layers: usize) -> Layout {
        let mut params = Vec::new();
        let mut off = 0usize;
        let mut push = |params: &mut Vec<ParamInfo>, name: String, role, shape: Vec<usize>| {
            let numel: usize = shape.iter().product();
            params.push(ParamInfo { name, role, offset: off, shape });
            off += numel;
        };
        push(&mut params, "embed.tok".into(), Role::Embed, vec![vocab, d]);
        for i in 0..n_layers {
            push(&mut params, format!("layers.{i}.attn_norm"), Role::Norm, vec![d]);
            for w in ["wq", "wk", "wv", "wo"] {
                push(&mut params, format!("layers.{i}.{w}"), Role::Linear, vec![d, d]);
            }
            push(&mut params, format!("layers.{i}.ffn_norm"), Role::Norm, vec![d]);
            push(&mut params, format!("layers.{i}.w_gate"), Role::Linear, vec![d, ff]);
            push(&mut params, format!("layers.{i}.w_up"), Role::Linear, vec![d, ff]);
            push(&mut params, format!("layers.{i}.w_down"), Role::Linear, vec![ff, d]);
        }
        push(&mut params, "final_norm".into(), Role::Norm, vec![d]);
        push(&mut params, "output".into(), Role::Output, vec![d, vocab]);
        let padded = (off + 1023) / 1024 * 1024;
        Layout::new(params, padded)
    }
}

/// A flat-vector optimizer. `lr` arrives from the coordinator's schedule
/// each step; `step()` must leave padding lanes untouched.
pub trait Optimizer: Send {
    fn name(&self) -> String;

    /// Apply one update in place. `grads.len() == params.len()`.
    fn step(&mut self, params: &mut [f32], grads: &[f32], lr: f32);

    /// Number of f32 state values currently allocated (the paper's
    /// "additional memory overhead" — Table 2 parenthetical numbers).
    fn state_floats(&self) -> usize;

    /// Hook: called by the trainer so projection-based methods know the
    /// global step for their update-frequency-T logic. Default: no-op.
    fn begin_step(&mut self, _global_step: u64) {}
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_layout_roles() {
        let l = Layout::synthetic(64, 16, 40, 3);
        assert_eq!(l.n_layers(), 3);
        assert_eq!(l.linears().count(), 3 * 7);
        assert!(l.flat_size <= l.padded_size);
        assert_eq!(l.padded_size % 1024, 0);
        // offsets are contiguous
        let mut off = 0;
        for p in &l.params {
            assert_eq!(p.offset, off);
            off += p.numel();
        }
        assert_eq!(off, l.flat_size);
    }

    #[test]
    fn layout_fingerprint_is_stable_and_shape_sensitive() {
        let a = Layout::synthetic(64, 16, 40, 3);
        let b = Layout::synthetic(64, 16, 40, 3);
        assert_eq!(a.fingerprint(), b.fingerprint(), "same shape, same fingerprint");
        // Any shape change — depth, width, vocab — moves the hash.
        for other in [
            Layout::synthetic(64, 16, 40, 2),
            Layout::synthetic(64, 24, 40, 3),
            Layout::synthetic(128, 16, 40, 3),
        ] {
            assert_ne!(a.fingerprint(), other.fingerprint());
        }
        // Human-auditable suffix: param count + flat/padded sizes.
        let fp = a.fingerprint();
        assert!(fp.contains(&format!("-f{}", a.flat_size)), "{fp}");
        assert!(fp.contains(&format!("-P{}", a.padded_size)), "{fp}");
    }

    #[test]
    fn param_info_layer_parse() {
        let p = ParamInfo {
            name: "layers.11.wq".into(),
            role: Role::Linear,
            offset: 0,
            shape: vec![4, 4],
        };
        assert_eq!(p.layer(), Some(11));
        let e = ParamInfo { name: "embed.tok".into(), role: Role::Embed, offset: 0, shape: vec![4] };
        assert_eq!(e.layer(), None);
    }

    #[test]
    fn dims_of_vector_param() {
        let p =
            ParamInfo { name: "n".into(), role: Role::Norm, offset: 0, shape: vec![7] };
        assert_eq!(p.dims(), (1, 7));
    }
}
