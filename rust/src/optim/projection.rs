//! Gradient projectors — the paper's subspace-selection menu (§4, Table 1).
//!
//! Matrix projectors (SVD / random semi-orthogonal) map a gradient matrix
//! G to a rank-r subspace and back; index projectors (RandK / columnwise /
//! blockwise) select coordinates. Memory footprints follow paper §C: SVD
//! and Random store the projection matrix P (the 26/24 factor of Table 2);
//! RandK stores only a seed; columnwise stores column indices; blockwise
//! stores block indices.


use crate::util::Prng;

use crate::linalg::{random_semi_orthogonal, svd};
use crate::tensor::Matrix;

/// Which side of G the projection multiplies (GaLore projects the smaller
/// dimension so P is (min_dim × r)).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Side {
    /// P: (m×r); down(G) = Pᵀ G (r×n); up(L) = P L.
    Left,
    /// P: (n×r); down(G) = G P (m×r); up(L) = L Pᵀ.
    Right,
}

/// A dense rank-r projector for one weight matrix.
#[derive(Clone, Debug)]
pub struct MatrixProjector {
    pub p: Matrix,
    pub side: Side,
}

impl MatrixProjector {
    /// GaLore-style: P = top-r singular vectors of G on the smaller side.
    pub fn from_svd(g: &Matrix, r: usize) -> Self {
        let d = svd(g);
        if g.rows <= g.cols {
            MatrixProjector { p: d.top_left(r.min(g.rows)), side: Side::Left }
        } else {
            MatrixProjector { p: d.top_right(r.min(g.cols)), side: Side::Right }
        }
    }

    /// Random semi-orthogonal P on the smaller side (paper §3.1 "Random").
    pub fn random(rows: usize, cols: usize, r: usize, rng: &mut Prng) -> Self {
        if rows <= cols {
            MatrixProjector { p: random_semi_orthogonal(rows, r.min(rows), rng), side: Side::Left }
        } else {
            MatrixProjector { p: random_semi_orthogonal(cols, r.min(cols), rng), side: Side::Right }
        }
    }

    pub fn rank(&self) -> usize {
        self.p.cols
    }

    /// Project a full gradient down to the low-rank space.
    pub fn down(&self, g: &Matrix) -> Matrix {
        match self.side {
            Side::Left => self.p.t_matmul(g),
            Side::Right => g.matmul(&self.p),
        }
    }

    /// Lift a low-rank update back to full size.
    pub fn up(&self, low: &Matrix) -> Matrix {
        match self.side {
            Side::Left => self.p.matmul(low),
            Side::Right => low.matmul_t(&self.p),
        }
    }

    /// Floats stored for this projector (paper §C memory accounting).
    pub fn floats(&self) -> usize {
        self.p.rows * self.p.cols
    }

    /// Rotation matrix R = P_newᵀ P_old used to re-project momentum when
    /// the subspace changes (paper §D / Hao et al. 2024 Alg. 2).
    pub fn rotation_from(&self, old: &MatrixProjector) -> Matrix {
        assert_eq!(self.side, old.side, "cannot rotate across sides");
        self.p.t_matmul(&old.p)
    }
}

/// Seed-reconstructible RandK index subset: k indices out of n, sampled
/// without replacement. Per paper §C, only the seed needs storing — the
/// indices are regenerated on demand, so the memory cost is O(1).
pub fn randk_indices(n: usize, k: usize, seed: u64) -> Vec<usize> {
    let k = k.min(n);
    let mut rng = Prng::seed_from_u64(seed);
    // Partial Fisher–Yates over a lazily-materialized permutation.
    let mut swaps: std::collections::HashMap<usize, usize> = Default::default();
    let mut out = Vec::with_capacity(k);
    for i in 0..k {
        let j = rng.range(i, n);
        let vi = *swaps.get(&i).unwrap_or(&i);
        let vj = *swaps.get(&j).unwrap_or(&j);
        out.push(vj);
        swaps.insert(j, vi);
    }
    out
}

/// Columnwise subset: k distinct column indices of a (·×cols) matrix.
pub fn column_subset(cols: usize, k: usize, rng: &mut Prng) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..cols).collect();
    for i in 0..k.min(cols) {
        let j = rng.range(i, cols);
        idx.swap(i, j);
    }
    idx.truncate(k.min(cols));
    idx.sort_unstable();
    idx
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn svd_projector_sides() {
        let mut rng = Prng::seed_from_u64(0);
        let wide = Matrix::randn(4, 10, 1.0, &mut rng);
        let tall = Matrix::randn(10, 4, 1.0, &mut rng);
        let pw = MatrixProjector::from_svd(&wide, 2);
        let pt = MatrixProjector::from_svd(&tall, 2);
        assert_eq!(pw.side, Side::Left);
        assert_eq!(pt.side, Side::Right);
        assert_eq!(pw.down(&wide).rows, 2);
        assert_eq!(pt.down(&tall).cols, 2);
    }

    #[test]
    fn down_up_is_projection() {
        // up(down(G)) projected twice equals projected once (idempotent).
        let mut rng = Prng::seed_from_u64(1);
        let g = Matrix::randn(8, 6, 1.0, &mut rng);
        let proj = MatrixProjector::from_svd(&g, 3);
        let once = proj.up(&proj.down(&g));
        let twice = proj.up(&proj.down(&once));
        assert!(once.sub(&twice).frobenius_norm() < 1e-4);
    }

    #[test]
    fn svd_projection_captures_more_energy_than_random() {
        // The paper's §3.1 observation: SVD better preserves gradient
        // spectrum at a single step.
        let mut rng = Prng::seed_from_u64(2);
        // Low-rank-dominant gradient.
        let u = Matrix::randn(16, 2, 3.0, &mut rng);
        let v = Matrix::randn(2, 12, 1.0, &mut rng);
        let g = u.matmul(&v).add(&Matrix::randn(16, 12, 0.1, &mut rng));
        let svd_p = MatrixProjector::from_svd(&g, 2);
        let rnd_p = MatrixProjector::random(16, 12, 2, &mut rng);
        let e_svd = svd_p.up(&svd_p.down(&g)).frobenius_norm();
        let e_rnd = rnd_p.up(&rnd_p.down(&g)).frobenius_norm();
        assert!(e_svd > e_rnd, "svd={e_svd} rnd={e_rnd}");
    }

    #[test]
    fn randk_reconstructible_and_distinct() {
        let a = randk_indices(1000, 100, 42);
        let b = randk_indices(1000, 100, 42);
        assert_eq!(a, b);
        let mut sorted = a.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 100, "duplicates found");
        assert!(sorted.iter().all(|&i| i < 1000));
        let c = randk_indices(1000, 100, 43);
        assert_ne!(a, c);
    }

    #[test]
    fn randk_full_is_permutation() {
        let mut a = randk_indices(50, 50, 7);
        a.sort_unstable();
        assert_eq!(a, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn column_subset_sorted_distinct() {
        let mut rng = Prng::seed_from_u64(5);
        let s = column_subset(64, 16, &mut rng);
        assert_eq!(s.len(), 16);
        assert!(s.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn rotation_identity_for_same_projector() {
        let mut rng = Prng::seed_from_u64(6);
        let p = MatrixProjector::random(12, 20, 4, &mut rng);
        let r = p.rotation_from(&p);
        for i in 0..4 {
            for j in 0..4 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((r[(i, j)] - want).abs() < 1e-4);
            }
        }
    }
}
