//! State-free optimizers (SGD, signSGD) and SGDM.
//!
//! signSGD (Bernstein et al., 2018) is the paper's state-free method of
//! choice (§4, Table 10): zero optimizer state, Adam-like update magnitude.

use super::Optimizer;

/// Plain SGD. Zero state.
pub struct Sgd;

impl Optimizer for Sgd {
    fn name(&self) -> String {
        "sgd".into()
    }

    fn step(&mut self, params: &mut [f32], grads: &[f32], lr: f32) {
        crate::tensor::axpy(-lr, grads, params);
    }

    fn state_floats(&self) -> usize {
        0
    }
}

/// signSGD without momentum. Zero state. `sign(0) == 0`, so padding lanes
/// (zero gradient) never move.
pub struct SignSgd;

/// The per-lane sign-step delta: `p_new = p − sign_delta(g, lr)`. The
/// SINGLE copy of the selection logic every sign-style update shares
/// (`sign_step` here, `engine::shard::sign_shard_update_into`) — the
/// bit-compatibility contract below is argued once, against this
/// function. (`f32::signum(0.0)` is ±1, not 0, hence the explicit zero
/// case.)
#[inline(always)]
pub fn sign_delta(g: f32, lr: f32) -> f32 {
    if g > 0.0 {
        lr
    } else if g < 0.0 {
        -lr
    } else {
        0.0
    }
}

/// The elementwise sign step, shared with FRUGAL's state-free branch.
///
/// Written as a branch-free-value update over fixed 16-lane chunks so it
/// autovectorizes: every lane computes `p -= d` with
/// `d = sign_delta(g, lr) ∈ {lr, −lr, 0}`. Bit-compatibility with the
/// historical branchy loop: `p − (−lr) = p + lr` exactly (IEEE-754
/// negation is sign-flip), and `p − 0.0 = p` bit-for-bit for every
/// non-NaN `p` including `−0.0` — so padding lanes (zero gradient)
/// still never move.
#[inline]
pub fn sign_step(params: &mut [f32], grads: &[f32], lr: f32) {
    const CHUNK: usize = 16;
    let n = params.len().min(grads.len());
    let split = n - n % CHUNK;
    let (p_main, p_tail) = params[..n].split_at_mut(split);
    let (g_main, g_tail) = grads[..n].split_at(split);
    for (pc, gc) in p_main.chunks_exact_mut(CHUNK).zip(g_main.chunks_exact(CHUNK)) {
        for k in 0..CHUNK {
            pc[k] -= sign_delta(gc[k], lr);
        }
    }
    for (p, &g) in p_tail.iter_mut().zip(g_tail) {
        *p -= sign_delta(g, lr);
    }
}

impl Optimizer for SignSgd {
    fn name(&self) -> String {
        "signsgd".into()
    }

    fn step(&mut self, params: &mut [f32], grads: &[f32], lr: f32) {
        sign_step(params, grads, lr);
    }

    fn state_floats(&self) -> usize {
        0
    }
}

/// SGD with (EMA-form) momentum: m <- (1-β) g + β m, p -= lr m.
/// The state-full rule of the paper's theory instance (Alg. 2).
pub struct Sgdm {
    pub beta: f32,
    pub m: Vec<f32>,
}

impl Sgdm {
    pub fn new(n: usize, beta: f32) -> Self {
        Sgdm { beta, m: vec![0.0; n] }
    }
}

impl Optimizer for Sgdm {
    fn name(&self) -> String {
        format!("sgdm(b={})", self.beta)
    }

    fn step(&mut self, params: &mut [f32], grads: &[f32], lr: f32) {
        for i in 0..params.len() {
            self.m[i] = (1.0 - self.beta) * grads[i] + self.beta * self.m[i];
            params[i] -= lr * self.m[i];
        }
    }

    fn state_floats(&self) -> usize {
        self.m.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sgd_step_is_linear() {
        let mut p = vec![1.0f32, 2.0];
        Sgd.step(&mut p, &[0.5, -1.0], 0.1);
        assert_eq!(p, vec![0.95, 2.1]);
    }

    #[test]
    fn signsgd_unit_moves() {
        let mut p = vec![0.0f32, 0.0, 0.0];
        SignSgd.step(&mut p, &[3.0, -0.001, 0.0], 0.01);
        assert_eq!(p, vec![-0.01, 0.01, 0.0]);
    }

    #[test]
    fn signsgd_zero_grad_fixed_point() {
        // The padding-lane invariant the fused kernel also relies on.
        let mut p = vec![1.23f32; 8];
        SignSgd.step(&mut p, &[0.0; 8], 1.0);
        assert_eq!(p, vec![1.23f32; 8]);
    }

    #[test]
    fn sgdm_matches_manual_recursion() {
        let mut opt = Sgdm::new(1, 0.9);
        let mut p = vec![0.0f32];
        let gs = [1.0f32, 2.0, -1.0];
        let mut m = 0.0f32;
        let mut want = 0.0f32;
        for g in gs {
            m = 0.1 * g + 0.9 * m;
            want -= 0.1 * m;
            opt.step(&mut p, &[g], 0.1);
        }
        assert!((p[0] - want).abs() < 1e-6);
    }

    #[test]
    fn state_sizes() {
        assert_eq!(Sgd.state_floats(), 0);
        assert_eq!(SignSgd.state_floats(), 0);
        assert_eq!(Sgdm::new(10, 0.9).state_floats(), 10);
    }

    #[test]
    fn sgdm_converges_on_quadratic() {
        let mut opt = Sgdm::new(2, 0.9);
        let mut x = vec![5.0f32, -3.0];
        for _ in 0..500 {
            let g: Vec<f32> = x.iter().map(|v| *v).collect();
            opt.step(&mut x, &g, 0.05);
        }
        assert!(x.iter().all(|v| v.abs() < 1e-2), "{x:?}");
    }
}
