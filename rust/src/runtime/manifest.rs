//! `artifacts/manifest.json` — the contract between aot.py and this crate.
//! Parsed with the in-tree JSON parser (offline build — no serde).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::Result;

use crate::optim::{Layout, ParamInfo, Role};
use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct ModelEntry {
    pub arch: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub seq_len: usize,
    pub batch: usize,
    pub flat_size: usize,
    pub padded_size: usize,
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
    pub weight_decay: f64,
    pub artifacts: HashMap<String, String>,
    pub params: Vec<ParamInfo>,
}

impl ModelEntry {
    fn from_json(v: &Json) -> Result<ModelEntry> {
        let mut artifacts = HashMap::new();
        for (k, val) in v.field("artifacts")?.as_obj()? {
            artifacts.insert(k.clone(), val.as_str()?.to_string());
        }
        let mut params = Vec::new();
        for p in v.field("params")?.as_arr()? {
            let shape = p
                .field("shape")?
                .as_arr()?
                .iter()
                .map(|s| s.as_usize())
                .collect::<Result<Vec<_>>>()?;
            params.push(ParamInfo {
                name: p.field("name")?.as_str()?.to_string(),
                role: match p.field("role")?.as_str()? {
                    "embed" => Role::Embed,
                    "norm" => Role::Norm,
                    "output" => Role::Output,
                    _ => Role::Linear,
                },
                offset: p.field("offset")?.as_usize()?,
                shape,
            });
        }
        Ok(ModelEntry {
            arch: v.field("arch")?.as_str()?.to_string(),
            vocab: v.field("vocab")?.as_usize()?,
            d_model: v.field("d_model")?.as_usize()?,
            n_layers: v.field("n_layers")?.as_usize()?,
            n_heads: v.field("n_heads")?.as_usize()?,
            d_ff: v.field("d_ff")?.as_usize()?,
            seq_len: v.field("seq_len")?.as_usize()?,
            batch: v.field("batch")?.as_usize()?,
            flat_size: v.field("flat_size")?.as_usize()?,
            padded_size: v.field("padded_size")?.as_usize()?,
            beta1: v.field("beta1")?.as_f64()?,
            beta2: v.field("beta2")?.as_f64()?,
            eps: v.field("eps")?.as_f64()?,
            weight_decay: v.field("weight_decay")?.as_f64()?,
            artifacts,
            params,
        })
    }

    /// Convert the manifest param table into the optimizer [`Layout`].
    pub fn layout(&self) -> Layout {
        Layout {
            params: self.params.clone(),
            flat_size: self.flat_size,
            padded_size: self.padded_size,
        }
    }

    /// Tokens per training batch.
    pub fn tokens_per_batch(&self) -> u64 {
        (self.batch * self.seq_len) as u64
    }
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub pad_block: usize,
    pub models: HashMap<String, ModelEntry>,
    pub optim: HashMap<String, String>,
    pub dir: PathBuf,
}

impl Manifest {
    pub fn parse(text: &str, dir: &Path) -> Result<Manifest> {
        let v = Json::parse(text)?;
        let mut models = HashMap::new();
        for (name, entry) in v.field("models")?.as_obj()? {
            models.insert(name.clone(), ModelEntry::from_json(entry)?);
        }
        let mut optim = HashMap::new();
        for (name, rel) in v.field("optim")?.as_obj()? {
            optim.insert(name.clone(), rel.as_str()?.to_string());
        }
        Ok(Manifest {
            pad_block: v.field("pad_block")?.as_usize()?,
            models,
            optim,
            dir: dir.to_path_buf(),
        })
    }

    pub fn load(dir: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json")).map_err(|e| {
            anyhow::anyhow!(
                "cannot read {}/manifest.json ({e}); run `make artifacts` first",
                dir.display()
            )
        })?;
        Self::parse(&text, dir)
    }

    pub fn model(&self, name: &str) -> Result<&ModelEntry> {
        self.models
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("model config '{name}' not in manifest"))
    }

    /// Absolute path of a model artifact ("eval" | "grad" | "step").
    pub fn artifact_path(&self, model: &str, kind: &str) -> Result<PathBuf> {
        let entry = self.model(model)?;
        let rel = entry
            .artifacts
            .get(kind)
            .ok_or_else(|| anyhow::anyhow!("artifact kind '{kind}' missing for '{model}'"))?;
        Ok(self.dir.join(rel))
    }

    /// Absolute path of an optimizer-only artifact by key name.
    pub fn optim_artifact(&self, key: &str) -> Result<PathBuf> {
        let rel = self
            .optim
            .get(key)
            .ok_or_else(|| anyhow::anyhow!("optimizer artifact '{key}' not in manifest"))?;
        Ok(self.dir.join(rel))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_json() -> &'static str {
        r#"{
          "pad_block": 1024,
          "models": {
            "test": {
              "arch": "llama", "vocab": 128, "d_model": 32, "n_layers": 2,
              "n_heads": 2, "d_ff": 88, "seq_len": 32, "batch": 4,
              "flat_size": 100, "padded_size": 1024,
              "beta1": 0.9, "beta2": 0.999, "eps": 1e-8, "weight_decay": 0.0,
              "artifacts": {"eval": "eval_test.hlo.txt"},
              "params": [
                {"name": "embed.tok", "role": "embed", "offset": 0, "shape": [8, 4]},
                {"name": "layers.0.wq", "role": "linear", "offset": 32, "shape": [4, 4]},
                {"name": "final_norm", "role": "norm", "offset": 48, "shape": [4]},
                {"name": "output", "role": "output", "offset": 52, "shape": [4, 8]}
              ]
            }
          },
          "optim": {"frugal_update_4096": "frugal_update_4096.hlo.txt"}
        }"#
    }

    #[test]
    fn parse_and_layout() {
        let man = Manifest::parse(sample_json(), Path::new("/tmp")).unwrap();
        let entry = man.models.get("test").unwrap();
        let layout = entry.layout();
        assert_eq!(layout.params.len(), 4);
        assert_eq!(layout.params[1].role, Role::Linear);
        assert_eq!(layout.params[0].role, Role::Embed);
        assert_eq!(layout.flat_size, 100);
        assert_eq!(entry.tokens_per_batch(), 128);
        assert!((entry.beta2 - 0.999).abs() < 1e-12);
    }

    #[test]
    fn missing_model_errors() {
        let man = Manifest::parse(sample_json(), Path::new("/tmp")).unwrap();
        assert!(man.model("nope").is_err());
        assert!(man.artifact_path("test", "step").is_err());
        assert!(man.optim_artifact("nope").is_err());
        assert!(man.artifact_path("test", "eval").is_ok());
    }
}
