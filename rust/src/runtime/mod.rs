//! PJRT runtime: load AOT HLO-text artifacts and execute them.
//!
//! Pattern follows /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`. HLO **text** is the interchange format
//! (jax ≥ 0.5 emits 64-bit-id protos that xla_extension 0.5.1 rejects).
//!
//! Python never runs here: artifacts are produced once by
//! `make artifacts` and the binary is self-contained afterwards.

mod manifest;

pub use manifest::{Manifest, ModelEntry};

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use crate::Result;

/// A compiled, ready-to-run computation.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

impl Executable {
    /// Execute with literal inputs; the artifact's tuple output is
    /// decomposed into its elements.
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self.exe.execute::<xla::Literal>(inputs)?;
        let lit = result[0][0].to_literal_sync()?;
        Ok(lit.to_tuple()?)
    }
}

/// The PJRT client plus a compile cache keyed by artifact path.
pub struct Runtime {
    client: xla::PjRtClient,
    cache: Mutex<HashMap<PathBuf, std::sync::Arc<Executable>>>,
}

impl Runtime {
    pub fn cpu() -> Result<Self> {
        Ok(Runtime { client: xla::PjRtClient::cpu()?, cache: Mutex::new(HashMap::new()) })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO-text artifact (cached).
    pub fn load(&self, path: &Path) -> Result<std::sync::Arc<Executable>> {
        if let Some(hit) = self.cache.lock().unwrap().get(path) {
            return Ok(hit.clone());
        }
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        let executable = std::sync::Arc::new(Executable {
            exe,
            name: path.file_stem().unwrap().to_string_lossy().into_owned(),
        });
        self.cache.lock().unwrap().insert(path.to_path_buf(), executable.clone());
        Ok(executable)
    }
}

// ---------------------------------------------------------------------------
// Literal helpers (marshalling between Vec<f32>/Vec<i32> and xla::Literal)
// ---------------------------------------------------------------------------

/// 1-D f32 literal.
pub fn lit_f32(data: &[f32]) -> xla::Literal {
    xla::Literal::vec1(data)
}

/// Shape-(1,) f32 scalar input (the aot.py scalar convention).
pub fn lit_scalar1(x: f32) -> xla::Literal {
    xla::Literal::vec1(&[x])
}

/// (rows × cols) i32 literal from row-major data.
pub fn lit_i32_2d(data: &[i32], rows: usize, cols: usize) -> Result<xla::Literal> {
    anyhow::ensure!(data.len() == rows * cols, "token buffer shape mismatch");
    Ok(xla::Literal::vec1(data).reshape(&[rows as i64, cols as i64])?)
}

/// Extract a Vec<f32> from a literal.
pub fn to_vec_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

/// Extract the scalar f32 from a rank-0 literal.
pub fn to_scalar_f32(lit: &xla::Literal) -> Result<f32> {
    Ok(lit.get_first_element::<f32>()?)
}
