//! Linear batch-size warmup: the global batch as a function of tokens
//! consumed.
//!
//! Psyche-style `global_batch_size_start/end/warmup_tokens`: the global
//! batch (micro-batches per optimizer step, `grad_accum`) increases
//! linearly from `start` to `end` over the first `warmup_tokens` training
//! tokens, then holds `end`. The engine applies the schedule at **round
//! boundaries** (one round = `update_freq` steps) — the same boundary
//! where the subspace re-selects and all shard state re-provisions — so
//! a changing batch composes with variable-ρ re-provisioning without a
//! second lifecycle.
//!
//! Determinism contract, mirroring [`super::RhoSchedule`]:
//! [`BatchSchedule::size_at`] is a pure function of the token count and
//! [`BatchPlan::accum_for_round`] is a pure function of the round number
//! — the token count it feeds from is *reconstructed* from the round
//! counter, never read back from a telemetry counter — so `workers 1 ≡
//! workers N` and `resume ≡ continuous` stay bitwise under a warming
//! batch. The canonical spec string (the [`std::fmt::Display`] form,
//! accepted back by [`BatchSchedule::parse`]) is the schedule's
//! checkpoint fingerprint: a resume under a different batch schedule is
//! rejected up front instead of silently replaying different data.
//!
//! Spec grammar (CLI `--batch-schedule` and the `[schedule.batch]`
//! config section compile to the same values):
//!
//! ```text
//! M (or constant:M)        fixed global batch (the classic grad_accum knob)
//! linear:START:END:TOKENS  linear START → END micro-batches over TOKENS
//!                          training tokens, then hold END
//! ```

use crate::Result;

/// A global-batch schedule over tokens consumed (see the module docs).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum BatchSchedule {
    /// Fixed global batch — the behavior of the scalar `grad_accum` knob.
    Constant { batch: usize },
    /// Linear interpolation `start → end` micro-batches over
    /// `warmup_tokens` tokens; token counts at or past `warmup_tokens`
    /// hold `end`. `start ≤ end` (the batch only grows), so global
    /// micro-batch indices stay strictly increasing across rounds.
    Linear { start: usize, end: usize, warmup_tokens: u64 },
}

impl BatchSchedule {
    /// The constant schedule at `batch` — what a scalar `grad_accum`
    /// config knob compiles to.
    pub fn constant(batch: usize) -> BatchSchedule {
        BatchSchedule::Constant { batch }
    }

    /// Parse the canonical spec string (see module docs for the
    /// grammar). [`std::fmt::Display`] emits the same form, so
    /// `parse(format!("{s}"))` round-trips every schedule exactly.
    pub fn parse(spec: &str) -> Result<BatchSchedule> {
        let int = |s: &str| -> Result<u64> {
            s.parse::<u64>()
                .map_err(|e| anyhow::anyhow!("bad count '{s}' in batch schedule '{spec}': {e}"))
        };
        let parts: Vec<&str> = spec.split(':').collect();
        let sched = match parts.as_slice() {
            // A bare number is the constant schedule (and its canonical
            // Display form — identical to the legacy grad_accum knob).
            &[m] if m.parse::<u64>().is_ok() => {
                BatchSchedule::Constant { batch: int(m)? as usize }
            }
            &["constant", m] => BatchSchedule::Constant { batch: int(m)? as usize },
            &["linear", s, e, t] => BatchSchedule::Linear {
                start: int(s)? as usize,
                end: int(e)? as usize,
                warmup_tokens: int(t)?,
            },
            _ => anyhow::bail!(
                "unknown batch schedule '{spec}' (expected constant:M | \
                 linear:START:END:TOKENS)"
            ),
        };
        sched.validate()?;
        Ok(sched)
    }

    /// Reject out-of-range parameters with a config-time error (a bad
    /// batch must not surface as a zero-micro-batch step mid-run).
    pub fn validate(&self) -> Result<()> {
        match *self {
            BatchSchedule::Constant { batch } => {
                anyhow::ensure!(batch >= 1, "batch schedule needs batch >= 1");
            }
            BatchSchedule::Linear { start, end, warmup_tokens } => {
                anyhow::ensure!(start >= 1, "batch schedule needs start >= 1");
                anyhow::ensure!(
                    start <= end,
                    "batch schedule start {start} exceeds end {end} — the global batch \
                     only warms up (shrinking it would fold micro-batch indices back \
                     onto already-consumed data)"
                );
                anyhow::ensure!(
                    warmup_tokens >= 1,
                    "batch schedule needs warmup_tokens >= 1 (write a bare constant \
                     instead of a zero-length warmup)"
                );
            }
        }
        Ok(())
    }

    /// Global batch (micro-batches per step) after `tokens` training
    /// tokens — a pure integer function (no floats, no state).
    pub fn size_at(&self, tokens: u64) -> usize {
        match *self {
            BatchSchedule::Constant { batch } => batch,
            BatchSchedule::Linear { start, end, warmup_tokens } => {
                if tokens >= warmup_tokens {
                    end
                } else {
                    // Integer floor interpolation; u128 keeps the product
                    // exact for any u64 token count.
                    let span = (end - start) as u128;
                    start + (span * tokens as u128 / warmup_tokens as u128) as usize
                }
            }
        }
    }

    /// The largest batch the schedule ever reaches — what the engine
    /// provisions for (residual slots, checkpoint `grad_accum`).
    pub fn peak(&self) -> usize {
        match *self {
            BatchSchedule::Constant { batch } => batch,
            BatchSchedule::Linear { end, .. } => end,
        }
    }
}

impl std::fmt::Display for BatchSchedule {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match *self {
            // Bare number: matches the legacy fixed-grad_accum spelling,
            // so constant schedules fingerprint as the plain knob.
            BatchSchedule::Constant { batch } => write!(f, "{batch}"),
            BatchSchedule::Linear { start, end, warmup_tokens } => {
                write!(f, "linear:{start}:{end}:{warmup_tokens}")
            }
        }
    }
}

/// A [`BatchSchedule`] bound to a run's geometry: how many tokens one
/// micro-batch carries and how many steps one round lasts. This is what
/// the engine consults at every round boundary.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BatchPlan {
    pub schedule: BatchSchedule,
    /// Tokens per micro-batch (model batch × seq_len).
    pub tokens_per_micro: u64,
    /// Steps per round (`update_freq`).
    pub steps_per_round: u64,
}

impl BatchPlan {
    pub fn new(schedule: BatchSchedule, tokens_per_micro: u64, steps_per_round: u64) -> BatchPlan {
        BatchPlan { schedule, tokens_per_micro, steps_per_round }
    }

    /// `grad_accum` for the 1-based round `round` — a pure function of
    /// the round number: the token count entering each round is
    /// reconstructed by replaying the schedule round by round, never
    /// read back from a counter, so a restore recomputes the active
    /// batch from the manifest's round alone. O(round) integer work,
    /// called once per round boundary.
    pub fn accum_for_round(&self, round: u64) -> usize {
        let mut tokens = 0u64;
        for _ in 1..round {
            let ga = self.schedule.size_at(tokens) as u64;
            tokens = tokens
                .saturating_add(self.steps_per_round.saturating_mul(ga).saturating_mul(
                    self.tokens_per_micro,
                ));
        }
        self.schedule.size_at(tokens)
    }

    /// The largest `grad_accum` any round uses (provisioning bound).
    pub fn peak(&self) -> usize {
        self.schedule.peak()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_display_roundtrip_every_kind() {
        for spec in ["8", "linear:2:8:4096"] {
            let s = BatchSchedule::parse(spec).unwrap();
            assert_eq!(format!("{s}"), spec, "display must be canonical");
            let back = BatchSchedule::parse(&format!("{s}")).unwrap();
            assert_eq!(back, s);
            for t in [0u64, 1, 100, 4096, u64::MAX] {
                assert_eq!(back.size_at(t), s.size_at(t), "tokens {t}");
            }
        }
    }

    #[test]
    fn constant_holds_and_matches_the_legacy_knob_form() {
        let s = BatchSchedule::constant(8);
        for t in [0u64, 1, 1 << 40] {
            assert_eq!(s.size_at(t), 8);
        }
        assert_eq!(format!("{s}"), "8");
        assert_eq!(BatchSchedule::parse("constant:8").unwrap(), s);
        assert_eq!(BatchSchedule::parse("8").unwrap(), s);
        assert_eq!(s.peak(), 8);
    }

    #[test]
    fn linear_hits_endpoints_floors_and_holds() {
        let s = BatchSchedule::parse("linear:2:8:600").unwrap();
        assert_eq!(s.size_at(0), 2);
        assert_eq!(s.size_at(99), 2); // floor: 2 + 6*99/600 = 2
        assert_eq!(s.size_at(100), 3);
        assert_eq!(s.size_at(300), 5);
        assert_eq!(s.size_at(599), 7); // 2 + 6*599/600 = 7 (floor)
        assert_eq!(s.size_at(600), 8);
        assert_eq!(s.size_at(u64::MAX), 8);
        assert_eq!(s.peak(), 8);
    }

    #[test]
    fn warmup_is_monotone_non_decreasing() {
        let s = BatchSchedule::parse("linear:1:16:1000").unwrap();
        let mut prev = 0usize;
        for t in 0..1100u64 {
            let b = s.size_at(t);
            assert!(b >= prev, "tokens {t}: {b} < {prev}");
            assert!((1..=16).contains(&b), "tokens {t}: {b}");
            prev = b;
        }
    }

    #[test]
    fn bad_specs_are_rejected() {
        for spec in [
            "bogus:4",
            "linear:2:8",       // missing tokens
            "linear:2:8:0",     // zero-length warmup
            "linear:0:8:100",   // zero start
            "linear:8:2:100",   // shrinking batch
            "constant:0",
            "constant:abc",
            "linear:2:8:-1",
            "",
        ] {
            assert!(BatchSchedule::parse(spec).is_err(), "'{spec}' should be rejected");
        }
    }

    #[test]
    fn plan_replays_tokens_round_by_round() {
        // tokens_per_micro 10, 5 steps/round, warmup 2→4 over 400 tokens:
        // round 1 @ ga 2 consumes 5*2*10 = 100 → round 2 @ size_at(100)
        // = 2 + 2*100/400 = 2; round 2 consumes another 100 → round 3 @
        // size_at(200) = 3; round 3 consumes 150 → round 4 @ size_at(350)
        // = 3; round 4 → size_at(500) = 4; held thereafter.
        let plan = BatchPlan::new(BatchSchedule::parse("linear:2:4:400").unwrap(), 10, 5);
        assert_eq!(plan.accum_for_round(1), 2);
        assert_eq!(plan.accum_for_round(2), 2);
        assert_eq!(plan.accum_for_round(3), 3);
        assert_eq!(plan.accum_for_round(4), 3);
        assert_eq!(plan.accum_for_round(5), 4);
        assert_eq!(plan.accum_for_round(100), 4);
        assert_eq!(plan.peak(), 4);
    }

    #[test]
    fn plan_is_a_pure_function_of_the_round() {
        // Same round → same answer, in any query order (no hidden state).
        let plan = BatchPlan::new(BatchSchedule::parse("linear:1:8:5000").unwrap(), 64, 10);
        let forward: Vec<usize> = (1..20).map(|r| plan.accum_for_round(r)).collect();
        let backward: Vec<usize> = (1..20).rev().map(|r| plan.accum_for_round(r)).collect();
        let reversed: Vec<usize> = backward.into_iter().rev().collect();
        assert_eq!(forward, reversed);
        // Monotone non-decreasing round over round.
        for w in forward.windows(2) {
            assert!(w[0] <= w[1]);
        }
    }

    #[test]
    fn constant_plan_matches_the_plain_knob() {
        let plan = BatchPlan::new(BatchSchedule::constant(4), 128, 50);
        for r in [1u64, 2, 17, 1000] {
            assert_eq!(plan.accum_for_round(r), 4);
        }
    }
}
