//! Adaptive density schedules: ρ as a function of the mask epoch.
//!
//! FRUGAL treats the state-full fraction ρ as a fixed knob; this module
//! lets it *decay* over training the way AdaRankGrad anneals gradient
//! rank — full-rank updates early (large state-full subspace), cheap
//! near-signSGD updates late. A [`RhoSchedule`] maps the 0-based **mask
//! epoch** (the subspace re-selection round counter — one epoch per
//! `update_freq` steps) to a density in `[0, 1]`; the `MaskBuilder`
//! consults it at every `advance()`, so the state-full lane count
//! K(epoch) shrinks and the engine elastically re-provisions its shard /
//! compression plans and Adam moment pools on every epoch whose K
//! changes.
//!
//! Determinism contract: `rho_at` is a pure function of the epoch (plain
//! f64 arithmetic, no RNG), so the headline invariants — `workers 1 ≡
//! workers N` and `resume ≡ continuous`, bitwise — hold under a changing
//! ρ exactly as they do under a fixed one. The canonical spec string
//! (the [`std::fmt::Display`] form, accepted back by
//! [`RhoSchedule::parse`]) doubles as the schedule's checkpoint
//! fingerprint: a resume under a different schedule is rejected up
//! front instead of silently diverging at the next re-selection.
//!
//! Spec grammar (CLI `--rho-schedule` and the `[schedule]` config
//! section compile to the same values):
//!
//! ```text
//! RHO (or constant:RHO)         fixed density (the classic FRUGAL knob)
//! linear:START:END:EPOCHS       linear START → END over EPOCHS epochs, then hold END
//! cosine:START:END:EPOCHS       half-cosine START → END over EPOCHS epochs, then hold
//! step:START:FACTOR:EVERY:MIN   multiply by FACTOR every EVERY epochs, floored at MIN
//! ```
//!
//! The canonical (Display) form of a constant schedule is the bare
//! number — exactly what the pre-schedule fixed-ρ fingerprint recorded
//! — so snapshots taken before this subsystem existed keep resuming
//! under an equal constant ρ.

use crate::Result;

mod batch;

pub use batch::{BatchPlan, BatchSchedule};

/// A density schedule over mask epochs (see the module docs).
#[derive(Clone, Debug, PartialEq)]
pub enum RhoSchedule {
    /// Fixed ρ — the behavior of the scalar `rho` config knob.
    Constant { rho: f64 },
    /// Linear interpolation `start → end` over `epochs` epochs; epochs at
    /// or past `epochs` hold `end`.
    Linear { start: f64, end: f64, epochs: u64 },
    /// Half-cosine `start → end` over `epochs` epochs, then hold `end`.
    Cosine { start: f64, end: f64, epochs: u64 },
    /// Geometric decay: `start · factor^(epoch / every)`, floored at
    /// `min`.
    Step { start: f64, factor: f64, every: u64, min: f64 },
}

impl RhoSchedule {
    /// The constant schedule at `rho` — what a scalar `rho` config knob
    /// compiles to.
    pub fn constant(rho: f64) -> RhoSchedule {
        RhoSchedule::Constant { rho }
    }

    /// Parse the canonical spec string (see module docs for the
    /// grammar). [`std::fmt::Display`] emits the same form, so
    /// `parse(format!("{s}"))` round-trips every schedule exactly.
    pub fn parse(spec: &str) -> Result<RhoSchedule> {
        let num = |s: &str| -> Result<f64> {
            s.parse::<f64>()
                .map_err(|e| anyhow::anyhow!("bad number '{s}' in rho schedule '{spec}': {e}"))
        };
        let int = |s: &str| -> Result<u64> {
            s.parse::<u64>()
                .map_err(|e| anyhow::anyhow!("bad count '{s}' in rho schedule '{spec}': {e}"))
        };
        let parts: Vec<&str> = spec.split(':').collect();
        let sched = match parts.as_slice() {
            // A bare number is the constant schedule (and its canonical
            // Display form — identical to the legacy fixed-ρ knob).
            &[r] if r.parse::<f64>().is_ok() => RhoSchedule::Constant { rho: num(r)? },
            &["constant", r] => RhoSchedule::Constant { rho: num(r)? },
            &["linear", s, e, n] => {
                RhoSchedule::Linear { start: num(s)?, end: num(e)?, epochs: int(n)? }
            }
            &["cosine", s, e, n] => {
                RhoSchedule::Cosine { start: num(s)?, end: num(e)?, epochs: int(n)? }
            }
            &["step", s, f, n, m] => RhoSchedule::Step {
                start: num(s)?,
                factor: num(f)?,
                every: int(n)?,
                min: num(m)?,
            },
            _ => anyhow::bail!(
                "unknown rho schedule '{spec}' (expected constant:RHO | \
                 linear:START:END:EPOCHS | cosine:START:END:EPOCHS | \
                 step:START:FACTOR:EVERY:MIN)"
            ),
        };
        sched.validate()?;
        Ok(sched)
    }

    /// Reject out-of-range parameters with a config-time error (a bad ρ
    /// must not surface as a silently-clamped mask mid-run).
    pub fn validate(&self) -> Result<()> {
        let rho_ok = |name: &str, r: f64| -> Result<()> {
            anyhow::ensure!(
                (0.0..=1.0).contains(&r),
                "rho schedule {name} {r} outside [0, 1]"
            );
            Ok(())
        };
        match *self {
            RhoSchedule::Constant { rho } => rho_ok("value", rho)?,
            RhoSchedule::Linear { start, end, epochs }
            | RhoSchedule::Cosine { start, end, epochs } => {
                rho_ok("start", start)?;
                rho_ok("end", end)?;
                anyhow::ensure!(epochs >= 1, "rho schedule needs epochs >= 1");
            }
            RhoSchedule::Step { start, factor, every, min } => {
                rho_ok("start", start)?;
                rho_ok("min", min)?;
                anyhow::ensure!(
                    factor > 0.0 && factor <= 1.0,
                    "rho schedule step factor {factor} outside (0, 1]"
                );
                anyhow::ensure!(every >= 1, "rho schedule needs step_every >= 1");
                anyhow::ensure!(
                    min <= start,
                    "rho schedule floor {min} exceeds its start {start}"
                );
            }
        }
        Ok(())
    }

    /// Density of the 0-based mask epoch `epoch` — a pure f64 function
    /// (no RNG, no state), always in `[0, 1]`.
    pub fn rho_at(&self, epoch: u64) -> f64 {
        let r = match *self {
            RhoSchedule::Constant { rho } => rho,
            RhoSchedule::Linear { start, end, epochs } => {
                if epoch >= epochs {
                    end
                } else {
                    start + (end - start) * (epoch as f64 / epochs as f64)
                }
            }
            RhoSchedule::Cosine { start, end, epochs } => {
                if epoch >= epochs {
                    end
                } else {
                    let t = epoch as f64 / epochs as f64;
                    end + (start - end) * 0.5 * (1.0 + (std::f64::consts::PI * t).cos())
                }
            }
            RhoSchedule::Step { start, factor, every, min } => {
                // powi is O(log k), so the exponent only needs clamping
                // to its i32 domain; factor < 1 underflows toward 0 for
                // huge epochs, which the floor absorbs.
                let k = (epoch / every.max(1)).min(i32::MAX as u64) as i32;
                (start * factor.powi(k)).max(min)
            }
        };
        r.clamp(0.0, 1.0)
    }

}

impl std::fmt::Display for RhoSchedule {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match *self {
            // Bare number: byte-identical to the legacy fixed-ρ
            // fingerprint, so pre-schedule snapshots keep resuming.
            RhoSchedule::Constant { rho } => write!(f, "{rho}"),
            RhoSchedule::Linear { start, end, epochs } => {
                write!(f, "linear:{start}:{end}:{epochs}")
            }
            RhoSchedule::Cosine { start, end, epochs } => {
                write!(f, "cosine:{start}:{end}:{epochs}")
            }
            RhoSchedule::Step { start, factor, every, min } => {
                write!(f, "step:{start}:{factor}:{every}:{min}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_display_roundtrip_every_kind() {
        for spec in [
            "0.25",
            "linear:0.5:0.1:8",
            "cosine:0.5:0.1:8",
            "step:0.4:0.5:2:0.05",
        ] {
            let s = RhoSchedule::parse(spec).unwrap();
            assert_eq!(format!("{s}"), spec, "display must be canonical");
            let back = RhoSchedule::parse(&format!("{s}")).unwrap();
            assert_eq!(back, s);
            for e in 0..20u64 {
                assert_eq!(back.rho_at(e).to_bits(), s.rho_at(e).to_bits(), "epoch {e}");
            }
        }
    }

    #[test]
    fn constant_holds_and_matches_the_legacy_fingerprint_form() {
        let s = RhoSchedule::constant(0.25);
        for e in [0u64, 1, 7, 1000] {
            assert_eq!(s.rho_at(e), 0.25);
        }
        // Canonical form is the bare number — exactly what pre-schedule
        // fixed-ρ fingerprints recorded — and the explicit `constant:`
        // spelling parses to the same schedule.
        assert_eq!(format!("{s}"), "0.25");
        assert_eq!(RhoSchedule::parse("constant:0.25").unwrap(), s);
        assert_eq!(RhoSchedule::parse("0.25").unwrap(), s);
    }

    #[test]
    fn linear_hits_endpoints_and_holds() {
        let s = RhoSchedule::parse("linear:0.5:0.1:4").unwrap();
        assert_eq!(s.rho_at(0), 0.5);
        assert!((s.rho_at(2) - 0.3).abs() < 1e-12);
        assert_eq!(s.rho_at(4), 0.1);
        assert_eq!(s.rho_at(100), 0.1);
    }

    #[test]
    fn cosine_hits_endpoints_and_midpoint() {
        let s = RhoSchedule::parse("cosine:0.5:0.1:4").unwrap();
        assert_eq!(s.rho_at(0), 0.5);
        // cos(pi/2) = 0 -> midpoint of start/end.
        assert!((s.rho_at(2) - 0.3).abs() < 1e-12);
        assert_eq!(s.rho_at(4), 0.1);
        assert_eq!(s.rho_at(10), 0.1);
    }

    #[test]
    fn step_decays_on_cadence_and_floors() {
        let s = RhoSchedule::parse("step:0.4:0.5:2:0.05").unwrap();
        assert_eq!(s.rho_at(0), 0.4);
        assert_eq!(s.rho_at(1), 0.4);
        assert_eq!(s.rho_at(2), 0.2);
        assert_eq!(s.rho_at(3), 0.2);
        assert_eq!(s.rho_at(4), 0.1);
        assert_eq!(s.rho_at(6), 0.05);
        assert_eq!(s.rho_at(1000), 0.05, "floored, even at huge epochs");
    }

    #[test]
    fn decaying_schedules_are_monotone_non_increasing() {
        for spec in ["linear:0.6:0.1:9", "cosine:0.6:0.1:9", "step:0.6:0.7:3:0.1"] {
            let s = RhoSchedule::parse(spec).unwrap();
            let mut prev = f64::INFINITY;
            for e in 0..30u64 {
                let r = s.rho_at(e);
                assert!((0.0..=1.0).contains(&r), "{spec} epoch {e}: {r}");
                assert!(r <= prev + 1e-15, "{spec} epoch {e}: {r} > {prev}");
                prev = r;
            }
        }
    }

    #[test]
    fn bad_specs_are_rejected() {
        for spec in [
            "bogus:0.5",
            "linear:0.5:0.1",          // missing epochs
            "linear:0.5:0.1:0",        // zero epochs
            "linear:1.5:0.1:4",        // rho out of range
            "constant:-0.1",
            "constant:abc",
            "step:0.4:0.0:2:0.05",     // zero factor
            "step:0.4:1.5:2:0.05",     // factor > 1
            "step:0.4:0.5:0:0.05",     // zero cadence
            "step:0.1:0.5:2:0.4",      // floor above start
            "",
        ] {
            assert!(RhoSchedule::parse(spec).is_err(), "'{spec}' should be rejected");
        }
    }

    #[test]
    fn edge_densities_zero_and_one_are_valid() {
        // The K=0 / K=total endpoints the engine must provision for.
        let zero = RhoSchedule::parse("constant:0").unwrap();
        assert_eq!(zero.rho_at(3), 0.0);
        let full = RhoSchedule::parse("constant:1").unwrap();
        assert_eq!(full.rho_at(3), 1.0);
        let to_zero = RhoSchedule::parse("linear:1:0:4").unwrap();
        assert_eq!(to_zero.rho_at(0), 1.0);
        assert_eq!(to_zero.rho_at(9), 0.0);
    }
}
