//! Unified telemetry: deterministic counters + wall-clock flight recorder.
//!
//! One [`Telemetry`] registry is threaded through the engine, the
//! orchestrator, the reduce-tree metering, the buffer pool, and the
//! checkpoint writer. It keeps two strictly separated planes:
//!
//! 1. **Deterministic counters** ([`Counter::deterministic`]): pure
//!    functions of the training math — wire bytes per codec/lane-group,
//!    encode/combine/decode invocation counts, pool grabs, mask-epoch
//!    re-provision events, EF-residual resets, micro-batch counts. They
//!    are bit-identical across `workers 1 ≡ N` and across
//!    `resume ≡ continuous`, are captured into checkpoints so resumed
//!    runs continue (not restart) their totals, and are exported as a
//!    canonical sorted-key JSON manifest that CI diffs exactly.
//! 2. **Process counters + wall-clock spans**: values that depend on the
//!    execution strategy or on this process's lifetime — pool misses
//!    (threaded vs logical paths interleave grab/recycle differently),
//!    snapshot bytes (a resumed run does not re-write its predecessor's
//!    snapshots), straggler timeouts — plus per-step phase timings in a
//!    fixed-capacity ring-buffer [`FlightRecorder`] with power-of-two
//!    histograms. Nothing in this plane may feed back into training
//!    decisions that must replay deterministically.
//!
//! The steady-state allocation pin (the counting-allocator test) holds
//! with telemetry enabled: counters are plain `u64` adds, span capture
//! is two `Instant::now` calls writing into a preallocated ring, and
//! the ring only (re)allocates when reconfigured at startup.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::time::Instant;

use crate::util::json::escape;

/// Every counter the registry tracks. The discriminant is the index
/// into the backing array; deterministic-plane counters come first so
/// the checkpointed word vector is a prefix-ordered slice.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(usize)]
pub enum Counter {
    // ---- deterministic plane (persisted, identity-gated) ----
    /// Optimizer steps completed.
    Steps,
    /// Micro-batch gradients pushed into the reduce tree (leaf messages).
    MicroBatches,
    /// Encoded bytes that crossed reduce-tree edges.
    WireBytes,
    /// What the same messages would have cost at raw fp32.
    WireDenseBytes,
    /// Tree messages: leaf sends plus interior combine outputs.
    WireMessages,
    /// Encoded bytes attributable to the state-full lane group
    /// (split-layout messages only; dense messages have no groups).
    WireFullBytes,
    /// Encoded bytes attributable to the state-free lane group.
    WireFreeBytes,
    /// Leaf encode invocations (one per micro-batch message).
    EncodeLeafCalls,
    /// Interior decode-combine-reencode invocations.
    CombineCalls,
    /// Root decodes back to the padded flat gradient (one per step).
    DecodeRootCalls,
    /// Pooled message buffers drawn (`BufferPool` grabs; the draw
    /// count is a pure function of `grad_accum`, so it is deterministic
    /// even though *misses* are not).
    PoolGrabs,
    /// Mask-epoch re-provision events (subspace re-selection rounds).
    Reprovisions,
    /// EF-residual bank resets at round boundaries (0 when EF is off).
    EfResets,
    /// Training tokens consumed (summed micro-batch token counts — a
    /// pure function of the data plane's batch geometry, identical at
    /// any worker count; the batch-warmup schedule is *checked against*
    /// this total in tests but never reads it back).
    TokensConsumed,
    /// Sequences assigned to training micro-batches (0 when the driver
    /// does not declare its per-micro sequence count).
    SequencesAssigned,
    /// Summed per-leaf state-free codec error shares, in millionths of
    /// the group's pre-encode signal energy (see
    /// [`crate::engine::LeafSignal`]). Deterministic: commutative u64
    /// sums of integer micros, so identical at any worker count,
    /// arrival order, or transport — the adaptive codec controller's
    /// only quality feed.
    FreeErrShareMicro,
    /// Summed per-leaf state-full codec error shares (millionths).
    FullErrShareMicro,
    /// Adaptive-controller codec re-selections (mask epochs whose
    /// assignment changed). Deterministic: a pure function of the
    /// error-share counters above.
    CodecReselections,
    // ---- process plane (not persisted, not identity-gated) ----
    /// Pool grabs that minted a fresh buffer (execution-strategy
    /// dependent: threaded pre-draw vs logical interleaving).
    PoolMisses,
    /// Snapshot payload bytes written by this process.
    SnapshotBytes,
    /// Snapshot files written by this process.
    SnapshotFiles,
    /// Snapshots committed (manifest published) by this process.
    SnapshotsCommitted,
    /// Straggler micro-batches dropped after a collect timeout.
    StragglerTimeouts,
    /// Serialized frames that crossed a socket transport, both
    /// directions (0 under the in-memory transport: frames are moved,
    /// never serialized). Process plane: framing and control traffic
    /// depend on membership timing, not on the training math.
    TransportFrames,
    /// Serialized bytes that crossed a socket transport, length
    /// prefixes and control frames included — the actual wire cost, as
    /// opposed to the deterministic `WireBytes` payload accounting.
    TransportBytes,
    /// Rounds replayed after a mid-round worker loss (`[parallel.fault]`
    /// recovery). Process plane: faults are wall-clock events; the
    /// replayed steps reproduce the deterministic plane bit-exactly, so
    /// recovery never shows up there.
    RoundsRetried,
    /// Coordinator-spawned worker processes relaunched by the respawn
    /// supervisor.
    WorkersRespawned,
    /// Members evicted after dying mid-round or timing out (orderly
    /// leaves are not evictions).
    WorkersEvicted,
    /// Inbound frames rejected by the wire codec's CRC-32 trailer
    /// before reaching gradient math.
    FramesRejected,
    /// Micro-batches rejected at the encoder for a non-finite (NaN/Inf)
    /// gradient lane. Process plane: the poisoned batch never enters
    /// the reduce tree, so the deterministic trace of a recovered run
    /// is the trace that never saw it.
    NonFiniteGrads,
}

/// Counters in the deterministic plane (array prefix).
pub const DET_COUNTERS: usize = 18;
/// Total registry width.
pub const NUM_COUNTERS: usize = 30;

impl Counter {
    /// Every counter, in array order.
    pub const ALL: [Counter; NUM_COUNTERS] = [
        Counter::Steps,
        Counter::MicroBatches,
        Counter::WireBytes,
        Counter::WireDenseBytes,
        Counter::WireMessages,
        Counter::WireFullBytes,
        Counter::WireFreeBytes,
        Counter::EncodeLeafCalls,
        Counter::CombineCalls,
        Counter::DecodeRootCalls,
        Counter::PoolGrabs,
        Counter::Reprovisions,
        Counter::EfResets,
        Counter::TokensConsumed,
        Counter::SequencesAssigned,
        Counter::FreeErrShareMicro,
        Counter::FullErrShareMicro,
        Counter::CodecReselections,
        Counter::PoolMisses,
        Counter::SnapshotBytes,
        Counter::SnapshotFiles,
        Counter::SnapshotsCommitted,
        Counter::StragglerTimeouts,
        Counter::TransportFrames,
        Counter::TransportBytes,
        Counter::RoundsRetried,
        Counter::WorkersRespawned,
        Counter::WorkersEvicted,
        Counter::FramesRejected,
        Counter::NonFiniteGrads,
    ];

    /// Canonical snake_case key (manifest JSON, trace rendering).
    pub fn name(self) -> &'static str {
        match self {
            Counter::Steps => "steps",
            Counter::MicroBatches => "micro_batches",
            Counter::WireBytes => "wire_bytes",
            Counter::WireDenseBytes => "wire_dense_bytes",
            Counter::WireMessages => "wire_messages",
            Counter::WireFullBytes => "wire_full_bytes",
            Counter::WireFreeBytes => "wire_free_bytes",
            Counter::EncodeLeafCalls => "encode_leaf_calls",
            Counter::CombineCalls => "combine_calls",
            Counter::DecodeRootCalls => "decode_root_calls",
            Counter::PoolGrabs => "pool_grabs",
            Counter::Reprovisions => "reprovisions",
            Counter::EfResets => "ef_resets",
            Counter::TokensConsumed => "tokens_consumed",
            Counter::SequencesAssigned => "sequences_assigned",
            Counter::FreeErrShareMicro => "free_err_share_micro",
            Counter::FullErrShareMicro => "full_err_share_micro",
            Counter::CodecReselections => "codec_reselections",
            Counter::PoolMisses => "pool_misses",
            Counter::SnapshotBytes => "snapshot_bytes",
            Counter::SnapshotFiles => "snapshot_files",
            Counter::SnapshotsCommitted => "snapshots_committed",
            Counter::StragglerTimeouts => "straggler_timeouts",
            Counter::TransportFrames => "transport_frames",
            Counter::TransportBytes => "transport_bytes",
            Counter::RoundsRetried => "rounds_retried",
            Counter::WorkersRespawned => "workers_respawned",
            Counter::WorkersEvicted => "workers_evicted",
            Counter::FramesRejected => "frames_rejected",
            Counter::NonFiniteGrads => "non_finite_grads",
        }
    }

    /// True for deterministic-plane counters (persisted in checkpoints,
    /// bit-identity gated in CI).
    pub fn deterministic(self) -> bool {
        (self as usize) < DET_COUNTERS
    }
}

/// Per-step phases the flight recorder times on the training thread.
///
/// On the logical-worker path every phase is observed directly. On the
/// threaded path `batch_fill`/`grad`/`encode` run on worker threads and
/// are not separable from the collector; there `reduce` covers the whole
/// collect (worker wait included) and the worker-side phases stay zero.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
#[repr(usize)]
pub enum Phase {
    #[default]
    BatchFill,
    Grad,
    Encode,
    Reduce,
    Decode,
    StepKernel,
    CkptHandoff,
    /// Time a batch fill spent waiting on the streaming-data prefetcher
    /// (process plane: ring occupancy depends on IO timing). Recorded
    /// post-run from the prefetcher's stall ring, keyed by micro-batch
    /// index rather than step.
    PrefetchStall,
    /// Wall-clock time one mid-round recovery took: from the loss being
    /// detected to the round replay completing (eviction + rewind +
    /// replayed steps). Keyed by the step the loss surfaced on.
    RecoveryStall,
}

/// Number of [`Phase`] variants.
pub const NUM_PHASES: usize = 9;

impl Phase {
    /// Every phase, in array order.
    pub const ALL: [Phase; NUM_PHASES] = [
        Phase::BatchFill,
        Phase::Grad,
        Phase::Encode,
        Phase::Reduce,
        Phase::Decode,
        Phase::StepKernel,
        Phase::CkptHandoff,
        Phase::PrefetchStall,
        Phase::RecoveryStall,
    ];

    /// Canonical snake_case key.
    pub fn name(self) -> &'static str {
        match self {
            Phase::BatchFill => "batch_fill",
            Phase::Grad => "grad",
            Phase::Encode => "encode",
            Phase::Reduce => "reduce",
            Phase::Decode => "decode",
            Phase::StepKernel => "step_kernel",
            Phase::CkptHandoff => "ckpt_handoff",
            Phase::PrefetchStall => "prefetch_stall",
            Phase::RecoveryStall => "recovery_stall",
        }
    }
}

/// Power-of-two histogram buckets: bucket 0 holds 0 ns, bucket `b`
/// holds `[2^(b-1), 2^b)` ns, bucket 63 is the overflow tail.
pub const HIST_BUCKETS: usize = 64;

/// Fixed-bucket duration histogram for one phase.
#[derive(Clone, Debug)]
pub struct PhaseHist {
    buckets: [u64; HIST_BUCKETS],
    count: u64,
    total_ns: u64,
    max_ns: u64,
}

impl Default for PhaseHist {
    fn default() -> Self {
        PhaseHist { buckets: [0; HIST_BUCKETS], count: 0, total_ns: 0, max_ns: 0 }
    }
}

impl PhaseHist {
    #[inline]
    fn record(&mut self, ns: u64) {
        self.buckets[bucket_of(ns)] += 1;
        self.count += 1;
        self.total_ns += ns;
        self.max_ns = self.max_ns.max(ns);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn total_ns(&self) -> u64 {
        self.total_ns
    }

    pub fn max_ns(&self) -> u64 {
        self.max_ns
    }

    /// Quantile estimate: the inclusive upper bound of the bucket where
    /// the cumulative count first reaches `q * count` (0 when empty).
    pub fn quantile_ns(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut seen = 0u64;
        for (b, &n) in self.buckets.iter().enumerate() {
            seen += n;
            if seen >= rank {
                return bucket_upper_ns(b).min(self.max_ns.max(1));
            }
        }
        self.max_ns
    }
}

#[inline]
fn bucket_of(ns: u64) -> usize {
    if ns == 0 {
        0
    } else {
        (64 - ns.leading_zeros() as usize).min(HIST_BUCKETS - 1)
    }
}

/// Inclusive upper bound of bucket `b` in nanoseconds.
fn bucket_upper_ns(b: usize) -> u64 {
    if b == 0 {
        0
    } else if b >= 63 {
        u64::MAX
    } else {
        (1u64 << b) - 1
    }
}

/// One recorded span: a phase's duration within one optimizer step.
#[derive(Clone, Copy, Debug, Default)]
pub struct SpanRecord {
    pub step: u64,
    pub phase: Phase,
    pub ns: u64,
}

/// Rendered summary of one phase (for traces and benches).
#[derive(Clone, Copy, Debug, Default)]
pub struct PhaseSummary {
    pub count: u64,
    pub total_ns: u64,
    pub p50_ns: u64,
    pub p99_ns: u64,
    pub max_ns: u64,
}

/// Fixed-capacity ring-buffer flight recorder for wall-clock spans.
///
/// The ring and histograms are preallocated; recording a span is
/// bucket math plus one slot overwrite — zero heap traffic, so the
/// engine's steady-state allocation pin holds with spans enabled.
#[derive(Debug)]
pub struct FlightRecorder {
    enabled: bool,
    ring: Vec<SpanRecord>,
    head: usize,
    len: usize,
    hists: [PhaseHist; NUM_PHASES],
}

/// Default ring capacity (spans, not steps: one step records several).
pub const DEFAULT_RING_CAPACITY: usize = 1024;

impl FlightRecorder {
    pub fn new(capacity: usize) -> FlightRecorder {
        FlightRecorder {
            enabled: true,
            ring: vec![SpanRecord::default(); capacity.max(1)],
            head: 0,
            len: 0,
            hists: Default::default(),
        }
    }

    /// Re-provision the ring (startup / config application only — this
    /// allocates).
    pub fn configure(&mut self, capacity: usize, enabled: bool) {
        self.ring = vec![SpanRecord::default(); capacity.max(1)];
        self.head = 0;
        self.len = 0;
        self.enabled = enabled;
    }

    #[inline]
    pub fn enabled(&self) -> bool {
        self.enabled
    }

    pub fn set_enabled(&mut self, enabled: bool) {
        self.enabled = enabled;
    }

    pub fn capacity(&self) -> usize {
        self.ring.len()
    }

    #[inline]
    pub fn record(&mut self, phase: Phase, step: u64, ns: u64) {
        if !self.enabled {
            return;
        }
        self.hists[phase as usize].record(ns);
        self.ring[self.head] = SpanRecord { step, phase, ns };
        self.head = (self.head + 1) % self.ring.len();
        self.len = (self.len + 1).min(self.ring.len());
    }

    pub fn hist(&self, phase: Phase) -> &PhaseHist {
        &self.hists[phase as usize]
    }

    pub fn summary(&self, phase: Phase) -> PhaseSummary {
        let h = self.hist(phase);
        PhaseSummary {
            count: h.count(),
            total_ns: h.total_ns(),
            p50_ns: h.quantile_ns(0.50),
            p99_ns: h.quantile_ns(0.99),
            max_ns: h.max_ns(),
        }
    }

    /// Retained spans, oldest first.
    pub fn spans(&self) -> impl Iterator<Item = SpanRecord> + '_ {
        let cap = self.ring.len();
        let start = (self.head + cap - self.len) % cap;
        (0..self.len).map(move |i| self.ring[(start + i) % cap])
    }
}

/// An in-flight span measurement (None when spans are disabled, so a
/// disabled recorder costs one branch and no clock reads).
#[derive(Debug)]
pub struct SpanTimer(Option<Instant>);

impl SpanTimer {
    /// A timer that records nothing (for pre-checked disabled paths).
    pub fn disabled() -> SpanTimer {
        SpanTimer(None)
    }

    /// Elapsed nanoseconds so far (0 when disabled).
    pub fn elapsed_ns(&self) -> u64 {
        self.0.map(|t0| t0.elapsed().as_nanos() as u64).unwrap_or(0)
    }
}

/// The telemetry registry: deterministic counter array + flight
/// recorder. Owned by the engine; all deterministic increments happen
/// on the collector/training thread (never on worker threads), which is
/// what makes `workers 1 ≡ N` hold bit-exactly.
#[derive(Debug)]
pub struct Telemetry {
    counters: [u64; NUM_COUNTERS],
    pub recorder: FlightRecorder,
}

impl Default for Telemetry {
    fn default() -> Self {
        Telemetry::new()
    }
}

impl Telemetry {
    pub fn new() -> Telemetry {
        Telemetry {
            counters: [0; NUM_COUNTERS],
            recorder: FlightRecorder::new(DEFAULT_RING_CAPACITY),
        }
    }

    #[inline]
    pub fn add(&mut self, c: Counter, n: u64) {
        self.counters[c as usize] += n;
    }

    #[inline]
    pub fn set(&mut self, c: Counter, v: u64) {
        self.counters[c as usize] = v;
    }

    pub fn get(&self, c: Counter) -> u64 {
        self.counters[c as usize]
    }

    /// Start a span (no clock read when the recorder is disabled).
    #[inline]
    pub fn begin(&self) -> SpanTimer {
        SpanTimer(self.recorder.enabled().then(Instant::now))
    }

    /// Close a span and record it under `phase` for `step`.
    #[inline]
    pub fn end(&mut self, timer: SpanTimer, phase: Phase, step: u64) {
        if let Some(t0) = timer.0 {
            self.recorder.record(phase, step, t0.elapsed().as_nanos() as u64);
        }
    }

    /// Record a pre-measured duration (for spans timed elsewhere, e.g.
    /// the checkpoint handoff stall).
    #[inline]
    pub fn record_ns(&mut self, phase: Phase, step: u64, ns: u64) {
        self.recorder.record(phase, step, ns);
    }

    /// The deterministic plane as checkpoint words (array-prefix order).
    pub fn deterministic_words(&self) -> Vec<u64> {
        self.counters[..DET_COUNTERS].to_vec()
    }

    /// Seed the deterministic plane from checkpoint words (shorter
    /// legacy vectors leave the tail at its current value).
    pub fn load_deterministic(&mut self, words: &[u64]) {
        for (slot, &w) in self.counters[..DET_COUNTERS].iter_mut().zip(words) {
            *slot = w;
        }
    }

    /// Canonical counter manifest: sorted keys, two top-level planes.
    /// CI diffs `.deterministic` exactly between runs; `.process` is
    /// informational.
    pub fn manifest_json(&self) -> String {
        let mut det = BTreeMap::new();
        let mut proc = BTreeMap::new();
        for c in Counter::ALL {
            let target = if c.deterministic() { &mut det } else { &mut proc };
            target.insert(c.name(), self.get(c));
        }
        let obj = |m: &BTreeMap<&str, u64>| {
            m.iter()
                .map(|(k, v)| format!("\"{}\":{}", escape(k), v))
                .collect::<Vec<_>>()
                .join(",")
        };
        format!(
            "{{\"deterministic\":{{{}}},\"process\":{{{}}},\"schema\":1}}",
            obj(&det),
            obj(&proc)
        )
    }

    /// Per-phase summaries as JSONL (one object per phase, fixed order).
    pub fn phases_jsonl(&self) -> String {
        let mut out = String::new();
        for p in Phase::ALL {
            let s = self.recorder.summary(p);
            let _ = writeln!(
                out,
                "{{\"phase\":\"{}\",\"count\":{},\"total_ns\":{},\"p50_ns\":{},\
                 \"p99_ns\":{},\"max_ns\":{}}}",
                p.name(),
                s.count,
                s.total_ns,
                s.p50_ns,
                s.p99_ns,
                s.max_ns
            );
        }
        out
    }

    /// Retained ring spans as JSONL, oldest first — the same record
    /// style as `coordinator/metrics.rs` step records (flat JSON object
    /// per line, parseable by `util::json`).
    pub fn spans_jsonl(&self) -> String {
        let mut out = String::new();
        for s in self.recorder.spans() {
            let _ = writeln!(
                out,
                "{{\"step\":{},\"phase\":\"{}\",\"ns\":{}}}",
                s.step,
                s.phase.name(),
                s.ns
            );
        }
        out
    }

    /// Write the exportable run trace into `dir`:
    /// `counters.json` (canonical manifest), `phases.jsonl` (per-phase
    /// summaries), `spans.jsonl` (retained flight-recorder ring). The
    /// caller adds `metrics.jsonl` via `Metrics::write_jsonl` to
    /// complete the run directory `frugal trace` renders.
    pub fn write_run_dir(&self, dir: &std::path::Path) -> std::io::Result<()> {
        std::fs::create_dir_all(dir)?;
        std::fs::write(dir.join("counters.json"), self.manifest_json())?;
        std::fs::write(dir.join("phases.jsonl"), self.phases_jsonl())?;
        std::fs::write(dir.join("spans.jsonl"), self.spans_jsonl())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    #[test]
    fn counter_names_unique_and_ordered() {
        let names: Vec<&str> = Counter::ALL.iter().map(|c| c.name()).collect();
        let mut dedup = names.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), NUM_COUNTERS, "duplicate counter names");
        // Array index == discriminant, deterministic prefix contiguous.
        for (i, c) in Counter::ALL.iter().enumerate() {
            assert_eq!(*c as usize, i);
            assert_eq!(c.deterministic(), i < DET_COUNTERS);
        }
    }

    #[test]
    fn manifest_is_canonical_sorted_json() {
        let mut t = Telemetry::new();
        t.add(Counter::WireBytes, 123);
        t.add(Counter::PoolMisses, 7);
        let text = t.manifest_json();
        let v = Json::parse(&text).unwrap();
        let det = v.field("deterministic").unwrap().as_obj().unwrap();
        assert_eq!(det.len(), DET_COUNTERS);
        assert_eq!(det["wire_bytes"].as_f64().unwrap(), 123.0);
        let proc = v.field("process").unwrap().as_obj().unwrap();
        assert_eq!(proc["pool_misses"].as_f64().unwrap(), 7.0);
        // Canonical: same counters -> byte-identical text; keys sorted.
        let mut t2 = Telemetry::new();
        t2.add(Counter::PoolMisses, 7);
        t2.add(Counter::WireBytes, 123);
        assert_eq!(text, t2.manifest_json());
        let det_section = text.split("\"process\"").next().unwrap();
        let keys: Vec<usize> = Counter::ALL
            .iter()
            .filter(|c| c.deterministic())
            .map(|c| det_section.find(&format!("\"{}\"", c.name())).unwrap())
            .collect();
        let mut names: Vec<&str> =
            Counter::ALL.iter().filter(|c| c.deterministic()).map(|c| c.name()).collect();
        names.sort_unstable();
        let sorted_pos: Vec<usize> = names
            .iter()
            .map(|n| det_section.find(&format!("\"{n}\"")).unwrap())
            .collect();
        let mut expect = keys.clone();
        expect.sort_unstable();
        assert_eq!(sorted_pos, expect, "manifest keys not sorted");
    }

    #[test]
    fn deterministic_words_roundtrip() {
        let mut t = Telemetry::new();
        for (i, c) in Counter::ALL.iter().enumerate() {
            t.set(*c, (i as u64 + 1) * 10);
        }
        let words = t.deterministic_words();
        assert_eq!(words.len(), DET_COUNTERS);
        let mut fresh = Telemetry::new();
        fresh.load_deterministic(&words);
        for c in Counter::ALL {
            if c.deterministic() {
                assert_eq!(fresh.get(c), t.get(c), "{}", c.name());
            } else {
                assert_eq!(fresh.get(c), 0, "{} leaked into det plane", c.name());
            }
        }
        // Legacy (shorter) vectors seed a prefix and leave the rest.
        let mut partial = Telemetry::new();
        partial.load_deterministic(&words[..2]);
        assert_eq!(partial.get(Counter::Steps), words[0]);
        assert_eq!(partial.get(Counter::MicroBatches), words[1]);
        assert_eq!(partial.get(Counter::WireBytes), 0);
    }

    #[test]
    fn histogram_buckets_and_quantiles() {
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(1), 1);
        assert_eq!(bucket_of(2), 2);
        assert_eq!(bucket_of(3), 2);
        assert_eq!(bucket_of(4), 3);
        assert_eq!(bucket_of(u64::MAX), 63);
        let mut h = PhaseHist::default();
        assert_eq!(h.quantile_ns(0.5), 0);
        for _ in 0..99 {
            h.record(100); // bucket 7, upper bound 127
        }
        h.record(1_000_000);
        assert_eq!(h.count(), 100);
        assert_eq!(h.quantile_ns(0.50), 127);
        assert!(h.quantile_ns(0.99) <= 127);
        assert!(h.quantile_ns(1.0) >= 1_000_000 / 2);
        assert_eq!(h.max_ns(), 1_000_000);
    }

    #[test]
    fn ring_wraps_and_keeps_newest() {
        let mut r = FlightRecorder::new(4);
        for i in 0..10u64 {
            r.record(Phase::Reduce, i, i * 100);
        }
        let spans: Vec<SpanRecord> = r.spans().collect();
        assert_eq!(spans.len(), 4);
        assert_eq!(spans.first().unwrap().step, 6);
        assert_eq!(spans.last().unwrap().step, 9);
        assert_eq!(r.hist(Phase::Reduce).count(), 10, "hist sees all spans, ring the tail");
        // Disabled recorder: no clock reads, no records.
        r.set_enabled(false);
        r.record(Phase::Reduce, 99, 1);
        assert_eq!(r.hist(Phase::Reduce).count(), 10);
    }

    #[test]
    fn spans_and_phases_jsonl_parse() {
        let mut t = Telemetry::new();
        t.record_ns(Phase::Decode, 3, 500);
        t.record_ns(Phase::StepKernel, 3, 1500);
        for line in t.phases_jsonl().lines().chain(t.spans_jsonl().lines()) {
            Json::parse(line).unwrap();
        }
        assert_eq!(t.phases_jsonl().lines().count(), NUM_PHASES);
        assert_eq!(t.spans_jsonl().lines().count(), 2);
    }

    #[test]
    fn span_timer_disabled_is_free() {
        let mut t = Telemetry::new();
        t.recorder.set_enabled(false);
        let timer = t.begin();
        assert_eq!(timer.elapsed_ns(), 0);
        t.end(timer, Phase::Grad, 1);
        assert_eq!(t.recorder.hist(Phase::Grad).count(), 0);
    }
}
