//! Software bfloat16 rounding (round-to-nearest-even).
//!
//! Used to reproduce the paper's precision experiments (Tables 3 and 9):
//! "pure bf16" training stores master weights and optimizer state in
//! bfloat16, which loses fine-grained updates. We simulate that storage
//! format by rounding values through bf16 after every update, exactly as a
//! bf16 tensor would quantize them.

/// Round an f32 to the nearest bfloat16-representable value (ties to even).
#[inline]
pub fn bf16_round(x: f32) -> f32 {
    let bits = x.to_bits();
    // bf16 keeps the top 16 bits of the f32. Round-to-nearest-even on the
    // truncated 16 bits.
    let lsb = (bits >> 16) & 1;
    let rounded = bits.wrapping_add(0x7fff + lsb);
    f32::from_bits(rounded & 0xffff_0000)
}

/// Round every element of a slice through bf16 storage.
pub fn bf16_round_slice(xs: &mut [f32]) {
    for x in xs {
        *x = bf16_round(*x);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_values_unchanged() {
        for &v in &[0.0f32, 1.0, -2.0, 0.5, 256.0, -0.09375] {
            assert_eq!(bf16_round(v), v, "{v} should be bf16-exact");
        }
    }

    #[test]
    fn rounding_error_bounded() {
        // bf16 has 8 significand bits: relative error <= 2^-8.
        for i in 1..1000 {
            let v = 1.0 + i as f32 * 1e-3;
            let r = bf16_round(v);
            assert!(((r - v) / v).abs() <= 1.0 / 256.0, "{v} -> {r}");
        }
    }

    #[test]
    fn small_update_is_lost() {
        // The Table 3 phenomenon in miniature: adding a tiny delta to a
        // bf16-stored weight is a no-op — master weights need f32.
        let w = bf16_round(1.0f32);
        let updated = bf16_round(w + 1e-4);
        assert_eq!(updated, w);
        // While an f32 master weight retains it.
        assert_ne!(w + 1e-4, w);
    }

    #[test]
    fn ties_round_to_even() {
        // 1.0 + 2^-9 is exactly halfway between 1.0 and 1.0 + 2^-8.
        let v = 1.0 + 2f32.powi(-9);
        assert_eq!(bf16_round(v), 1.0); // even significand wins
    }

    #[test]
    fn slice_rounding() {
        let mut xs = vec![1.0001f32; 8];
        bf16_round_slice(&mut xs);
        for x in &xs {
            assert_eq!(*x, 1.0);
        }
    }
}
