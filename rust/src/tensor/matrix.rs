//! Row-major dense f32 matrix.


use crate::util::Prng;

/// A row-major dense matrix of f32.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Matrix { rows, cols, data }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols);
        Matrix { rows, cols, data }
    }

    /// Standard-normal entries scaled by `std`.
    pub fn randn(rows: usize, cols: usize, std: f32, rng: &mut Prng) -> Self {
        let mut m = Self::zeros(rows, cols);
        for v in &mut m.data {
            *v = std * normal_sample(rng);
        }
        m
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn col(&self, j: usize) -> Vec<f32> {
        (0..self.rows).map(|i| self[(i, j)]).collect()
    }

    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t[(j, i)] = self[(i, j)];
            }
        }
        t
    }

    /// `self @ other` — blocked, cache-friendly i-k-j loop.
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let mut out = Matrix::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            let orow = &mut out.data[i * other.cols..(i + 1) * other.cols];
            for k in 0..self.cols {
                let a = self.data[i * self.cols + k];
                if a == 0.0 {
                    continue;
                }
                let brow = &other.data[k * other.cols..(k + 1) * other.cols];
                for (o, b) in orow.iter_mut().zip(brow) {
                    *o += a * b;
                }
            }
        }
        out
    }

    /// `self^T @ other` without materializing the transpose.
    pub fn t_matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.rows, other.rows, "t_matmul shape mismatch");
        let mut out = Matrix::zeros(self.cols, other.cols);
        for k in 0..self.rows {
            let arow = &self.data[k * self.cols..(k + 1) * self.cols];
            let brow = &other.data[k * other.cols..(k + 1) * other.cols];
            for (i, &a) in arow.iter().enumerate() {
                if a == 0.0 {
                    continue;
                }
                let orow = &mut out.data[i * other.cols..(i + 1) * other.cols];
                for (o, b) in orow.iter_mut().zip(brow) {
                    *o += a * b;
                }
            }
        }
        out
    }

    /// `self @ other^T` without materializing the transpose.
    pub fn matmul_t(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.cols, "matmul_t shape mismatch");
        let mut out = Matrix::zeros(self.rows, other.rows);
        for i in 0..self.rows {
            let arow = self.row(i);
            for j in 0..other.rows {
                out[(i, j)] = super::dot(arow, other.row(j));
            }
        }
        out
    }

    pub fn frobenius_norm(&self) -> f32 {
        super::norm(&self.data)
    }

    pub fn add(&self, other: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a + b).collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }

    pub fn sub(&self, other: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }

    pub fn scaled(&self, s: f32) -> Matrix {
        let data = self.data.iter().map(|a| a * s).collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }

    /// Gather the given columns into a new (rows × idx.len()) matrix.
    pub fn gather_cols(&self, idx: &[usize]) -> Matrix {
        let mut out = Matrix::zeros(self.rows, idx.len());
        for i in 0..self.rows {
            for (jj, &j) in idx.iter().enumerate() {
                out[(i, jj)] = self[(i, j)];
            }
        }
        out
    }

    /// Scatter-add columns of `src` into the given column positions.
    pub fn scatter_cols_add(&mut self, idx: &[usize], src: &Matrix) {
        assert_eq!(src.cols, idx.len());
        assert_eq!(src.rows, self.rows);
        for i in 0..self.rows {
            for (jj, &j) in idx.iter().enumerate() {
                self[(i, j)] += src[(i, jj)];
            }
        }
    }
}

impl std::ops::Index<(usize, usize)> for Matrix {
    type Output = f32;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f32 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f32 {
        &mut self.data[i * self.cols + j]
    }
}

/// Standard-normal sample (thin wrapper over [`Prng::normal`], kept for
/// call-site brevity across the crate).
pub fn normal_sample(rng: &mut Prng) -> f32 {
    rng.normal()
}

#[cfg(test)]
mod tests {
    use super::*;
    

    #[test]
    fn matmul_small() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Matrix::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn t_matmul_matches_explicit_transpose() {
        let mut rng = Prng::seed_from_u64(0);
        let a = Matrix::randn(5, 3, 1.0, &mut rng);
        let b = Matrix::randn(5, 4, 1.0, &mut rng);
        let fast = a.t_matmul(&b);
        let slow = a.transpose().matmul(&b);
        for (x, y) in fast.data.iter().zip(&slow.data) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn matmul_t_matches_explicit_transpose() {
        let mut rng = Prng::seed_from_u64(1);
        let a = Matrix::randn(4, 6, 1.0, &mut rng);
        let b = Matrix::randn(3, 6, 1.0, &mut rng);
        let fast = a.matmul_t(&b);
        let slow = a.matmul(&b.transpose());
        for (x, y) in fast.data.iter().zip(&slow.data) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn gather_scatter_roundtrip() {
        let mut rng = Prng::seed_from_u64(2);
        let a = Matrix::randn(3, 5, 1.0, &mut rng);
        let idx = [1usize, 4];
        let g = a.gather_cols(&idx);
        assert_eq!(g.col(0), a.col(1));
        assert_eq!(g.col(1), a.col(4));
        let mut z = Matrix::zeros(3, 5);
        z.scatter_cols_add(&idx, &g);
        assert_eq!(z.col(1), a.col(1));
        assert_eq!(z.col(0), vec![0.0; 3]);
    }

    #[test]
    fn randn_moments() {
        let mut rng = Prng::seed_from_u64(3);
        let m = Matrix::randn(100, 100, 2.0, &mut rng);
        let mean = m.data.iter().sum::<f32>() / 10_000.0;
        let var = m.data.iter().map(|x| x * x).sum::<f32>() / 10_000.0;
        assert!(mean.abs() < 0.1, "mean={mean}");
        assert!((var - 4.0).abs() < 0.3, "var={var}");
    }

    #[test]
    fn eye_and_transpose() {
        let e = Matrix::eye(3);
        assert_eq!(e.transpose(), e);
        assert_eq!(e.matmul(&e), e);
    }
}
