//! Minimal dense-linear-algebra substrate.
//!
//! The paper's algorithms operate on per-module weight matrices and on the
//! flat parameter vector. We deliberately avoid external ndarray crates:
//! the operations needed (gemm, transpose, norms, column gathers, bf16
//! rounding) are few, and owning them keeps the hot paths transparent to
//! profile and optimize (see EXPERIMENTS.md §Perf).

mod bf16;
pub mod matrix;

pub use bf16::{bf16_round, bf16_round_slice};
pub use matrix::Matrix;

/// Dot product of two equal-length slices.
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// Euclidean norm of a slice.
pub fn norm(a: &[f32]) -> f32 {
    dot(a, a).sqrt()
}

/// `y += alpha * x` (BLAS axpy).
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// In-place scale.
pub fn scale(a: &mut [f32], s: f32) {
    for v in a {
        *v *= s;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_norm_axpy() {
        let a = [1.0, 2.0, 3.0];
        let mut b = [4.0, 5.0, 6.0];
        assert_eq!(dot(&a, &b), 32.0);
        assert!((norm(&a) - 14f32.sqrt()).abs() < 1e-6);
        axpy(2.0, &a, &mut b);
        assert_eq!(b, [6.0, 9.0, 12.0]);
        let mut c = [1.0, -2.0];
        scale(&mut c, -3.0);
        assert_eq!(c, [-3.0, 6.0]);
    }
}
