//! Closed-form toy problems for the theory experiments.
//!
//! - [`QuadraticMatrix`]: min ‖W‖² over W ∈ ℝ^{10×10} — the paper's
//!   Figure 3 setup (GaLore-like SGDM with/without momentum re-projection).
//! - [`Quadratic`]: min ½ xᵀ diag(λ) x — convergence-rate checks against
//!   Theorem 5.2's step-size condition.
//! - [`galore_sgdm_toy`]: the exact Fig. 3 algorithm — rank-r random
//!   projection refreshed every T steps, SGDM in the projected space, with
//!   optional momentum re-projection + mass normalization.


use crate::util::Prng;

use crate::linalg::random_semi_orthogonal;
use crate::tensor::Matrix;

/// min ½ xᵀ diag(λ) x; ∇f = λ ⊙ x. L = max λ.
pub struct Quadratic {
    pub lambda: Vec<f32>,
}

impl Quadratic {
    pub fn new(lambda: Vec<f32>) -> Self {
        Quadratic { lambda }
    }

    pub fn loss(&self, x: &[f32]) -> f64 {
        x.iter().zip(&self.lambda).map(|(xi, li)| 0.5 * (li * xi * xi) as f64).sum()
    }

    pub fn grad(&self, x: &[f32], out: &mut [f32]) {
        for i in 0..x.len() {
            out[i] = self.lambda[i] * x[i];
        }
    }

    /// Stochastic gradient with additive N(0, σ²) noise per coordinate.
    pub fn stochastic_grad(&self, x: &[f32], sigma: f32, rng: &mut Prng, out: &mut [f32]) {
        for i in 0..x.len() {
            out[i] = self.lambda[i] * x[i]
                + sigma * crate::tensor::matrix::normal_sample(rng);
        }
    }

    pub fn smoothness(&self) -> f32 {
        self.lambda.iter().cloned().fold(0.0, f32::max)
    }
}

/// One trajectory of the Figure 3 experiment: GaLore-like SGDM on
/// min ‖W‖², W ∈ ℝ^{n×n}, rank-r random projection refreshed every `t`
/// steps. If `reproject`, momentum is rotated into the new subspace and
/// renormalized to preserve momentum mass (paper §D); otherwise it is kept
/// verbatim (original GaLore).
///
/// Returns the loss ‖W‖² at every step.
pub fn galore_sgdm_toy(
    n: usize,
    rank: usize,
    t: u64,
    steps: u64,
    lr: f32,
    beta: f32,
    reproject: bool,
    seed: u64,
) -> Vec<f64> {
    let mut rng = Prng::seed_from_u64(seed);
    let mut w = Matrix::randn(n, n, 1.0, &mut rng);
    let mut p = random_semi_orthogonal(n, rank, &mut rng);
    // Momentum lives in the projected space: (rank × n).
    let mut m = Matrix::zeros(rank, n);
    let mut losses = Vec::with_capacity(steps as usize);
    for step in 0..steps {
        if step > 0 && step % t == 0 {
            let p_new = random_semi_orthogonal(n, rank, &mut rng);
            if reproject {
                // m_new = (P_new^T P_old) m_old, then normalize by the
                // norm ratio to preserve momentum mass (§D / Fig. 3).
                let rot = p_new.t_matmul(&p);
                let m_rot = rot.matmul(&m);
                let old_norm = crate::tensor::norm(&m.data);
                let new_norm = crate::tensor::norm(&m_rot.data);
                let gain = if new_norm > 1e-12 { old_norm / new_norm } else { 0.0 };
                m = m_rot.scaled(gain);
            }
            // !reproject: keep stale m (different subspace) — GaLore.
            p = p_new;
        }
        losses.push((w.frobenius_norm() as f64).powi(2));
        // grad of ||W||^2 = 2W; project, momentum, lift, apply.
        let g = w.scaled(2.0);
        let g_low = p.t_matmul(&g);
        for i in 0..m.data.len() {
            m.data[i] = beta * m.data[i] + (1.0 - beta) * g_low.data[i];
        }
        let upd = p.matmul(&m);
        w = w.sub(&upd.scaled(lr));
    }
    losses
}

/// FRUGAL(SGDM, SGD) on a [`Quadratic`] — Algorithm 2, used by the theory
/// tests: momentum set J_k = coordinates selected i.i.d. with prob `p_sel`
/// each round of length `t`.
pub fn frugal_sgdm_quadratic(
    problem: &Quadratic,
    x0: &[f32],
    lr: f32,
    beta: f32,
    p_sel: f64,
    t: u64,
    steps: u64,
    sigma: f32,
    seed: u64,
) -> Vec<f64> {
    let d = x0.len();
    let mut rng = Prng::seed_from_u64(seed);
    let mut x = x0.to_vec();
    let mut m = vec![0.0f32; d];
    let mut mask = vec![false; d];
    let mut g = vec![0.0f32; d];
    let mut losses = Vec::with_capacity(steps as usize);
    
    for step in 0..steps {
        if step % t == 0 {
            for b in mask.iter_mut() {
                *b = rng.f64() < p_sel;
            }
        }
        losses.push(problem.loss(&x));
        problem.stochastic_grad(&x, sigma, &mut rng, &mut g);
        for j in 0..d {
            if mask[j] {
                m[j] = (1.0 - beta) * g[j] + beta * m[j];
                x[j] -= lr * m[j];
            } else {
                m[j] = 0.0; // buffer released outside J_k (Alg. 2 line 3)
                x[j] -= lr * g[j];
            }
        }
    }
    losses
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quadratic_grad_correct() {
        let q = Quadratic::new(vec![1.0, 4.0]);
        let mut g = vec![0.0; 2];
        q.grad(&[2.0, 3.0], &mut g);
        assert_eq!(g, vec![2.0, 12.0]);
        assert!((q.loss(&[2.0, 3.0]) - (2.0 + 18.0)) < 1e-9);
        assert_eq!(q.smoothness(), 4.0);
    }

    #[test]
    fn figure3_reprojection_converges_faster() {
        // The paper's Fig. 3 claim: with re-projection the toy problem
        // converges much faster. Average over a few seeds like the paper
        // (5 runs).
        let mut adv = 0;
        for seed in 0..5 {
            let with = galore_sgdm_toy(10, 3, 10, 300, 0.05, 0.9, true, seed);
            let without = galore_sgdm_toy(10, 3, 10, 300, 0.05, 0.9, false, seed);
            if with.last().unwrap() < without.last().unwrap() {
                adv += 1;
            }
        }
        assert!(adv >= 4, "re-projection won only {adv}/5 runs");
    }

    #[test]
    fn figure3_both_decrease() {
        let with = galore_sgdm_toy(10, 3, 10, 300, 0.05, 0.9, true, 0);
        let without = galore_sgdm_toy(10, 3, 10, 300, 0.05, 0.9, false, 0);
        assert!(with.last().unwrap() < &with[0]);
        assert!(without.last().unwrap() < &without[0]);
    }

    #[test]
    fn alg2_full_selection_is_sgdm_rate() {
        // With p_sel=1 (always J=[d]) and the Thm 5.2 step bound, the
        // deterministic quadratic converges.
        let q = Quadratic::new(vec![1.0; 8]);
        let beta = 0.9f32;
        let alpha = (1.0 - beta) / (q.smoothness() * (4.0 - beta + beta * beta));
        let losses =
            frugal_sgdm_quadratic(&q, &[5.0; 8], alpha, beta, 1.0, 10, 2000, 0.0, 0);
        assert!(losses.last().unwrap() < &1e-3, "final={}", losses.last().unwrap());
    }

    #[test]
    fn alg2_partial_selection_still_converges() {
        let q = Quadratic::new(vec![0.5, 1.0, 2.0, 4.0]);
        let beta = 0.9f32;
        let alpha = (1.0 - beta) / (q.smoothness() * (4.0 - beta + beta * beta));
        let losses =
            frugal_sgdm_quadratic(&q, &[3.0; 4], alpha, beta, 0.5, 5, 4000, 0.0, 1);
        assert!(losses.last().unwrap() < &1e-3, "final={}", losses.last().unwrap());
    }

    #[test]
    fn alg2_noise_floor_scales_with_sigma() {
        // Theorem 5.2: the stationary noise floor is O(L·α·σ²).
        let q = Quadratic::new(vec![1.0; 16]);
        let run = |sigma: f32| {
            let losses =
                frugal_sgdm_quadratic(&q, &[1.0; 16], 0.01, 0.9, 0.5, 10, 5000, sigma, 2);
            // average of the last 500 losses = stationary level
            losses[4500..].iter().sum::<f64>() / 500.0
        };
        let lo = run(0.1);
        let hi = run(1.0);
        assert!(hi > 5.0 * lo, "noise floor should grow ~sigma^2: lo={lo} hi={hi}");
    }
}
