//! End-to-end trainers binding runtime + coordinator.
//!
//! Two execution paths (DESIGN.md §2):
//! - [`FusedTrainer`]: the performance path. One PJRT call per step runs
//!   fwd + bwd + the Pallas `frugal_update` kernel; Rust supplies the
//!   subspace mask (re-built every T steps) and the scheduled LRs. Covers
//!   FRUGAL (any mask policy), full AdamW (mask ≡ 1 on real lanes) and
//!   pure signSGD (mask ≡ 0) — the fast cases of the paper's tables.
//! - [`GradTrainer`]: the flexibility path. The grad artifact returns
//!   (loss, grads) and any [`Optimizer`] from the suite consumes them in
//!   Rust — required by GaLore/BAdam/Fira/LDAdam/AdaMeM/LoRA which need
//!   host-side SVD / error feedback / adapters.
//!
//! A third, data-parallel path lives in [`crate::engine`]: N workers,
//! deterministic tree all-reduce, sharded FRUGAL state. It plugs into
//! either gradient provider — [`PjrtGradSource`] adapts the grad
//! artifact, `engine::RefLm` is the artifact-free reference model — and
//! shares the subspace cadence with the fused path via [`SubspaceClock`].


use crate::util::Prng;

use crate::coordinator::clip::clip_global_norm;
use crate::coordinator::metrics::Metrics;
use crate::coordinator::subspace::MaskBuilder;
use crate::coordinator::LrSchedule;
use crate::optim::{Optimizer, Role};
use crate::runtime::{lit_f32, lit_i32_2d, lit_scalar1, to_scalar_f32, to_vec_f32, Executable,
                     Manifest, ModelEntry, Runtime};
use crate::tensor::bf16_round_slice;
use crate::Result;

/// Initialize the flat parameter vector the same way model.init_params
/// does in python: N(0, 0.02) for weights, 1 for norm gains, 0 for norm
/// biases, 0 padding.
pub fn init_flat(entry: &ModelEntry, seed: u64) -> Vec<f32> {
    let mut rng = Prng::seed_from_u64(seed);
    let mut flat = vec![0.0f32; entry.padded_size];
    for p in &entry.layout().params {
        let dst = &mut flat[p.offset..p.offset + p.numel()];
        if p.role == Role::Norm {
            let fill = if p.name.ends_with(".b") { 0.0 } else { 1.0 };
            dst.iter_mut().for_each(|x| *x = fill);
        } else {
            for x in dst.iter_mut() {
                *x = 0.02 * crate::tensor::matrix::normal_sample(&mut rng);
            }
        }
    }
    flat
}

/// Common handles for one model config.
pub struct Session {
    pub entry: ModelEntry,
    pub eval_exe: std::sync::Arc<Executable>,
    pub predict_exe: Option<std::sync::Arc<Executable>>,
    pub model_name: String,
}

impl Session {
    pub fn open(rt: &Runtime, man: &Manifest, model: &str) -> Result<Session> {
        let entry = man.model(model)?.clone();
        let eval_exe = rt.load(&man.artifact_path(model, "eval")?)?;
        // predict is optional: older artifact trees may not have it.
        let predict_exe = man
            .artifact_path(model, "predict")
            .ok()
            .and_then(|p| rt.load(&p).ok());
        Ok(Session { entry, eval_exe, predict_exe, model_name: model.to_string() })
    }

    /// Last-token logits for a batch: (batch × vocab), row-major.
    pub fn predict(&self, flat: &[f32], tokens: &[i32]) -> Result<Vec<f32>> {
        let exe = self
            .predict_exe
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("predict artifact missing; re-run make artifacts"))?;
        let out = exe.run(&[
            lit_f32(flat),
            lit_i32_2d(tokens, self.entry.batch, self.entry.seq_len)?,
        ])?;
        to_vec_f32(&out[0])
    }

    /// Mean held-out loss over `batches` validation batches supplied by
    /// the closure (idx -> token buffer).
    pub fn eval_loss(
        &self,
        flat: &[f32],
        batches: u64,
        mut batch_fn: impl FnMut(u64) -> Vec<i32>,
    ) -> Result<f64> {
        let mut total = 0.0f64;
        for i in 0..batches {
            let tokens = batch_fn(i);
            let out = self.eval_exe.run(&[
                lit_f32(flat),
                lit_i32_2d(&tokens, self.entry.batch, self.entry.seq_len)?,
            ])?;
            total += to_scalar_f32(&out[0])? as f64;
        }
        Ok(total / batches as f64)
    }
}

// ---------------------------------------------------------------------------
// Subspace clock (shared by FusedTrainer and the data-parallel engine)
// ---------------------------------------------------------------------------

/// Tracks the training step against the subspace update period `T`:
/// which steps re-select the mask, and the Adam bias-correction counter
/// that restarts at each re-selection (matching the fused kernel's
/// state-reset semantics). One clock drives both the fused PJRT path and
/// `engine::Engine`, so their round boundaries are identical by
/// construction.
#[derive(Clone, Debug)]
pub struct SubspaceClock {
    update_freq: u64,
    step: u64,
    adam_t: u64,
}

impl SubspaceClock {
    pub fn new(update_freq: u64) -> SubspaceClock {
        SubspaceClock { update_freq: update_freq.max(1), step: 0, adam_t: 0 }
    }

    /// Advance one step. Returns `(step_index, reselect_due)` where
    /// `step_index` is the 0-based index of the step about to run and
    /// `reselect_due` says the subspace must be re-selected before it.
    pub fn tick(&mut self) -> (u64, bool) {
        let due = self.step % self.update_freq == 0;
        if due {
            self.adam_t = 0;
        }
        self.adam_t += 1;
        let step = self.step;
        self.step += 1;
        (step, due)
    }

    /// Steps completed so far.
    pub fn step(&self) -> u64 {
        self.step
    }

    /// 0-based mask epoch of the most recently ticked step: step `s`
    /// (0-based) belongs to epoch `s / T`. The engine consults this at
    /// every `begin_round` — it is the index a variable-ρ schedule is
    /// evaluated at, and it advances in lock-step with the
    /// `MaskBuilder`'s own round counter by construction.
    pub fn epoch(&self) -> u64 {
        self.step.saturating_sub(1) / self.update_freq
    }

    /// Reposition the clock at a checkpointed position (`step` completed
    /// steps, `adam_t` steps into the current subspace period) so a
    /// resumed run ticks on exactly like the uninterrupted one.
    pub fn restore_at(&mut self, step: u64, adam_t: u64) {
        self.step = step;
        self.adam_t = adam_t;
    }

    /// 1-based Adam step within the current subspace period.
    pub fn adam_t(&self) -> u64 {
        self.adam_t
    }

    pub fn update_freq(&self) -> u64 {
        self.update_freq
    }
}

// ---------------------------------------------------------------------------
// Fused path
// ---------------------------------------------------------------------------

/// Precision regime for master weights/state (paper Tables 3/9).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Precision {
    /// f32 master weights (the paper's mixed-precision stand-in).
    F32,
    /// Round params + optimizer state through bf16 after every step.
    PureBf16,
}

pub struct FusedTrainer {
    pub session: Session,
    step_exe: std::sync::Arc<Executable>,
    pub flat: Vec<f32>,
    m: Vec<f32>,
    v: Vec<f32>,
    mask: Vec<f32>,
    pub mask_builder: MaskBuilder,
    pub schedule: LrSchedule,
    pub peak_lr: f64,
    pub lr_free_mult: f64,
    pub precision: Precision,
    /// Step/period tracking, incl. the Adam bias-correction counter that
    /// restarts at each subspace change (freshly-reset state).
    pub clock: SubspaceClock,
    pub metrics: Metrics,
}

impl FusedTrainer {
    pub fn new(
        rt: &Runtime,
        man: &Manifest,
        model: &str,
        mask_builder: MaskBuilder,
        schedule: LrSchedule,
        peak_lr: f64,
        lr_free_mult: f64,
        update_freq: u64,
        seed: u64,
    ) -> Result<FusedTrainer> {
        let session = Session::open(rt, man, model)?;
        let step_exe = rt.load(&man.artifact_path(model, "step")?)?;
        let n = session.entry.padded_size;
        let flat = init_flat(&session.entry, seed);
        Ok(FusedTrainer {
            session,
            step_exe,
            flat,
            m: vec![0.0; n],
            v: vec![0.0; n],
            mask: Vec::new(),
            mask_builder,
            schedule,
            peak_lr,
            lr_free_mult,
            precision: Precision::F32,
            clock: SubspaceClock::new(update_freq),
            metrics: Metrics::new(),
        })
    }

    /// One fused train step on `tokens` (batch × seq, row-major).
    pub fn step(&mut self, tokens: &[i32]) -> Result<f32> {
        let (step, reselect) = self.clock.tick();
        if reselect {
            self.mask = self.mask_builder.advance();
        }
        let lr = self.schedule.lr(self.peak_lr, step) as f32;
        let lr_free = lr * self.lr_free_mult as f32;
        let entry = &self.session.entry;
        let out = self.step_exe.run(&[
            lit_f32(&self.flat),
            lit_f32(&self.m),
            lit_f32(&self.v),
            lit_f32(&self.mask),
            lit_i32_2d(tokens, entry.batch, entry.seq_len)?,
            lit_scalar1(lr),
            lit_scalar1(lr_free),
            lit_scalar1(self.clock.adam_t() as f32),
        ])?;
        let loss = to_scalar_f32(&out[0])?;
        self.flat = to_vec_f32(&out[1])?;
        self.m = to_vec_f32(&out[2])?;
        self.v = to_vec_f32(&out[3])?;
        if self.precision == Precision::PureBf16 {
            bf16_round_slice(&mut self.flat);
            bf16_round_slice(&mut self.m);
            bf16_round_slice(&mut self.v);
        }
        self.metrics.record(step + 1, loss, lr as f64, entry.tokens_per_batch());
        Ok(loss)
    }

    pub fn global_step(&self) -> u64 {
        self.clock.step()
    }
}

// ---------------------------------------------------------------------------
// Grad path
// ---------------------------------------------------------------------------

pub struct GradTrainer {
    pub session: Session,
    grad_exe: std::sync::Arc<Executable>,
    pub flat: Vec<f32>,
    pub optimizer: Box<dyn Optimizer>,
    pub schedule: LrSchedule,
    pub peak_lr: f64,
    pub clip: Option<f32>,
    pub precision: Precision,
    step: u64,
    pub metrics: Metrics,
}

impl GradTrainer {
    pub fn new(
        rt: &Runtime,
        man: &Manifest,
        model: &str,
        optimizer: Box<dyn Optimizer>,
        schedule: LrSchedule,
        peak_lr: f64,
        seed: u64,
    ) -> Result<GradTrainer> {
        let session = Session::open(rt, man, model)?;
        let grad_exe = rt.load(&man.artifact_path(model, "grad")?)?;
        let flat = init_flat(&session.entry, seed);
        Ok(GradTrainer {
            session,
            grad_exe,
            flat,
            optimizer,
            schedule,
            peak_lr,
            clip: None,
            precision: Precision::F32,
            step: 0,
            metrics: Metrics::new(),
        })
    }

    /// One grad-then-rust-optimizer step.
    pub fn step(&mut self, tokens: &[i32]) -> Result<f32> {
        let entry = &self.session.entry;
        let out = self.grad_exe.run(&[
            lit_f32(&self.flat),
            lit_i32_2d(tokens, entry.batch, entry.seq_len)?,
        ])?;
        let loss = to_scalar_f32(&out[0])?;
        let mut grads = to_vec_f32(&out[1])?;
        if let Some(max_norm) = self.clip {
            clip_global_norm(&mut grads, max_norm);
        }
        let lr = self.schedule.lr(self.peak_lr, self.step) as f32;
        self.optimizer.begin_step(self.step);
        self.optimizer.step(&mut self.flat, &grads, lr);
        if self.precision == Precision::PureBf16 {
            bf16_round_slice(&mut self.flat);
        }
        self.step += 1;
        self.metrics.record(self.step, loss, lr as f64, entry.tokens_per_batch());
        Ok(loss)
    }

    /// Loss + raw gradient without applying an update (Figure 2 gradient
    /// collection).
    pub fn loss_and_grad(&self, tokens: &[i32]) -> Result<(f32, Vec<f32>)> {
        let entry = &self.session.entry;
        let out = self.grad_exe.run(&[
            lit_f32(&self.flat),
            lit_i32_2d(tokens, entry.batch, entry.seq_len)?,
        ])?;
        Ok((to_scalar_f32(&out[0])?, to_vec_f32(&out[1])?))
    }

    pub fn global_step(&self) -> u64 {
        self.step
    }
}

// ---------------------------------------------------------------------------
// Engine adapter
// ---------------------------------------------------------------------------

/// A [`crate::engine::GradSource`] backed by the PJRT grad artifact — the
/// bridge between the AOT three-layer stack and the data-parallel engine.
/// PJRT handle thread-safety is backend-dependent, so this source is used
/// through `engine::Sources::Local` (logical workers on the caller
/// thread); the PJRT CPU client parallelizes internally.
pub struct PjrtGradSource {
    exe: std::sync::Arc<Executable>,
    /// Forward-only loss executable for evaluation (the grad artifact
    /// would compute + transfer a full gradient just to discard it).
    eval_exe: Option<std::sync::Arc<Executable>>,
    entry: ModelEntry,
}

impl PjrtGradSource {
    pub fn new(rt: &Runtime, man: &Manifest, model: &str) -> Result<PjrtGradSource> {
        let entry = man.model(model)?.clone();
        let exe = rt.load(&man.artifact_path(model, "grad")?)?;
        let eval_exe = man
            .artifact_path(model, "eval")
            .ok()
            .and_then(|p| rt.load(&p).ok());
        Ok(PjrtGradSource { exe, eval_exe, entry })
    }

    pub fn entry(&self) -> &ModelEntry {
        &self.entry
    }
}

impl crate::engine::GradSource for PjrtGradSource {
    fn padded_size(&self) -> usize {
        self.entry.padded_size
    }

    fn loss_and_grad(&mut self, flat: &[f32], tokens: &[i32]) -> Result<(f32, Vec<f32>)> {
        let out = self.exe.run(&[
            lit_f32(flat),
            lit_i32_2d(tokens, self.entry.batch, self.entry.seq_len)?,
        ])?;
        Ok((to_scalar_f32(&out[0])?, to_vec_f32(&out[1])?))
    }

    fn loss(&mut self, flat: &[f32], tokens: &[i32]) -> Result<f32> {
        match &self.eval_exe {
            Some(exe) => {
                let out = exe.run(&[
                    lit_f32(flat),
                    lit_i32_2d(tokens, self.entry.batch, self.entry.seq_len)?,
                ])?;
                to_scalar_f32(&out[0])
            }
            None => Ok(self.loss_and_grad(flat, tokens)?.0),
        }
    }
}

/// Deterministic task-batch sampler shared by fine-tuning drivers: cycles
/// training examples of a [`crate::data::ClassificationTask`].
pub fn task_batch(
    task: &crate::data::ClassificationTask,
    entry: &ModelEntry,
    step: u64,
    rng: &mut Prng,
) -> Vec<i32> {
    let _ = rng.next_u64(); // advance stream per call (mirrors shuffling)
    task.train_batch((step as usize * entry.batch) % task.cfg.train_examples, entry.batch)
}

// ---------------------------------------------------------------------------
// Fine-tuning harness (paper §7 experiments)
// ---------------------------------------------------------------------------

/// Fine-tune `base_flat` on one classification task with the given
/// optimizer and report test accuracy (argmax over the task's label-token
/// ids at the final position).
pub fn finetune_and_eval(
    rt: &Runtime,
    man: &Manifest,
    model: &str,
    base_flat: &[f32],
    task: &crate::data::ClassificationTask,
    optimizer: Box<dyn Optimizer>,
    steps: u64,
    peak_lr: f64,
    seed: u64,
) -> Result<f64> {
    let mut tr = GradTrainer::new(
        rt,
        man,
        model,
        optimizer,
        LrSchedule::ConstantWarmup { warmup: steps / 10 },
        peak_lr,
        seed,
    )?;
    tr.flat.copy_from_slice(base_flat);
    let entry = tr.session.entry.clone();
    for step in 0..steps {
        let tokens = task.train_batch((step as usize * entry.batch) % task.cfg.train_examples,
                                      entry.batch);
        tr.step(&tokens)?;
    }
    task_accuracy(&tr.session, &tr.flat, task)
}

/// Test-set accuracy of `flat` on `task` via the predict artifact.
pub fn task_accuracy(
    session: &Session,
    flat: &[f32],
    task: &crate::data::ClassificationTask,
) -> Result<f64> {
    let entry = &session.entry;
    let vocab = entry.vocab;
    let mut correct = 0usize;
    let mut total = 0usize;
    let n_test = task.cfg.test_examples;
    let mut idx = 0usize;
    while idx < n_test {
        let mut tokens = Vec::with_capacity(entry.batch * entry.seq_len);
        let mut labels = Vec::with_capacity(entry.batch);
        for b in 0..entry.batch {
            let ex = task.test_example((idx + b) % n_test);
            tokens.extend_from_slice(&ex.tokens);
            labels.push(ex.label);
        }
        let logits = session.predict(flat, &tokens)?;
        for (b, &label) in labels.iter().enumerate() {
            if idx + b >= n_test {
                break;
            }
            let row = &logits[b * vocab..(b + 1) * vocab];
            let mut best = 0usize;
            let mut best_v = f32::NEG_INFINITY;
            for c in 0..task.cfg.classes {
                let tok = task.label_token(c) as usize;
                if row[tok] > best_v {
                    best_v = row[tok];
                    best = c;
                }
            }
            correct += (best == label) as usize;
            total += 1;
        }
        idx += entry.batch;
    }
    Ok(correct as f64 / total.max(1) as f64)
}
