//! Tiny timing harness for the `benches/` binaries (criterion is not
//! available offline). Warmup + N timed iterations, reports median and
//! mean-absolute-deviation.

use std::time::Instant;

/// Timing summary in nanoseconds.
#[derive(Clone, Copy, Debug)]
pub struct Timing {
    pub median_ns: f64,
    pub mean_ns: f64,
    pub mad_ns: f64,
    pub iters: usize,
}

impl Timing {
    pub fn per_iter_ms(&self) -> f64 {
        self.median_ns / 1e6
    }

    /// Throughput given a per-iteration element count.
    pub fn elements_per_s(&self, elements: usize) -> f64 {
        elements as f64 / (self.median_ns / 1e9)
    }
}

impl std::fmt::Display for Timing {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{:.3} ms/iter (±{:.3}, n={})", self.median_ns / 1e6, self.mad_ns / 1e6,
               self.iters)
    }
}

/// Summarize raw nanosecond samples into a [`Timing`].
///
/// Sorts with `f64::total_cmp`: a NaN sample (e.g. from a
/// caller-computed derived metric) sorts after every number instead of
/// panicking the comparator mid-bench the way
/// `partial_cmp(..).unwrap()` did.
pub fn summarize(mut samples: Vec<f64>) -> Timing {
    assert!(!samples.is_empty(), "summarize needs at least one sample");
    samples.sort_by(|a, b| a.total_cmp(b));
    let median = samples[samples.len() / 2];
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let mad = samples.iter().map(|s| (s - median).abs()).sum::<f64>() / samples.len() as f64;
    Timing { median_ns: median, mean_ns: mean, mad_ns: mad, iters: samples.len() }
}

/// Time `f` with `warmup` unrecorded and `iters` recorded runs.
pub fn time_fn(warmup: usize, iters: usize, mut f: impl FnMut()) -> Timing {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    summarize(samples)
}

/// Render one machine-readable benchmark record as a JSON object line
/// (`{"bench":"...", "label":"...", <fields>}`) for downstream tooling.
/// Numeric fields are emitted as JSON numbers; non-finite values become
/// `null` (bare NaN/inf are not valid JSON).
pub fn json_record(bench: &str, label: &str, fields: &[(&str, f64)]) -> String {
    use std::fmt::Write;
    let mut out = String::new();
    let _ = write!(
        out,
        "{{\"bench\":\"{}\",\"label\":\"{}\"",
        crate::util::json::escape(bench),
        crate::util::json::escape(label)
    );
    for (key, value) in fields {
        if value.is_finite() {
            let _ = write!(out, ",\"{}\":{}", crate::util::json::escape(key), value);
        } else {
            let _ = write!(out, ",\"{}\":null", crate::util::json::escape(key));
        }
    }
    out.push('}');
    out
}

/// Write [`json_record`] lines to `path` — the `BENCH_*.json` artifact
/// format CI uploads to track the perf trajectory (one JSON object per
/// line, parseable by `util::json`).
pub fn write_json_records(
    path: impl AsRef<std::path::Path>,
    lines: &[String],
) -> std::io::Result<()> {
    use std::io::Write;
    let mut f = std::fs::File::create(path)?;
    for line in lines {
        writeln!(f, "{line}")?;
    }
    Ok(())
}

/// Print a paper-style table: header row then aligned cells.
pub fn print_table(title: &str, header: &[&str], rows: &[Vec<String>]) {
    println!("\n=== {title} ===");
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let fmt_row = |cells: &[String]| {
        cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:<width$}", c, width = widths.get(i).copied().unwrap_or(8)))
            .collect::<Vec<_>>()
            .join("  ")
    };
    println!("{}", fmt_row(&header.iter().map(|s| s.to_string()).collect::<Vec<_>>()));
    for row in rows {
        println!("{}", fmt_row(row));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_measures_work() {
        let t = time_fn(1, 5, || {
            let mut x = 0u64;
            for i in 0..10_000 {
                x = x.wrapping_add(i);
            }
            std::hint::black_box(x);
        });
        assert!(t.median_ns > 0.0);
        assert_eq!(t.iters, 5);
        assert!(t.per_iter_ms() < 1e3);
    }

    #[test]
    fn throughput_math() {
        let t = Timing { median_ns: 1e9, mean_ns: 1e9, mad_ns: 0.0, iters: 1 };
        assert!((t.elements_per_s(1000) - 1000.0).abs() < 1e-6);
    }

    #[test]
    fn summarize_survives_nan_samples() {
        // Regression: the old partial_cmp(..).unwrap() comparator
        // panicked on any NaN sample. Under the total order NaN sorts
        // last, so the median of a mostly-finite set stays finite.
        let t = summarize(vec![3.0, f64::NAN, 1.0]);
        assert_eq!(t.iters, 3);
        assert!(t.median_ns.is_finite());
        assert_eq!(t.median_ns, 3.0);
        // All-finite behavior unchanged.
        let t = summarize(vec![5.0, 1.0, 3.0]);
        assert_eq!(t.median_ns, 3.0);
        assert!((t.mean_ns - 3.0).abs() < 1e-12);
    }

    #[test]
    fn json_records_file_roundtrip() {
        let path = std::env::temp_dir().join("frugal_bench_records_test.json");
        let lines = vec![
            json_record("b", "l1", &[("v", 1.0)]),
            json_record("b", "l2", &[("v", 2.0)]),
        ];
        write_json_records(&path, &lines).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 2);
        for line in text.lines() {
            assert!(crate::util::json::Json::parse(line).is_ok());
        }
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn json_record_is_valid_json() {
        let line = json_record(
            "parallel_scaling",
            "workers=4",
            &[("steps_per_s", 12.5), ("speedup", f64::NAN)],
        );
        assert_eq!(
            line,
            "{\"bench\":\"parallel_scaling\",\"label\":\"workers=4\",\
             \"steps_per_s\":12.5,\"speedup\":null}"
        );
        // Round-trips through the in-tree parser.
        let parsed = crate::util::json::Json::parse(&line).unwrap();
        assert_eq!(parsed.field("bench").unwrap().as_str().unwrap(), "parallel_scaling");
        assert!((parsed.field("steps_per_s").unwrap().as_f64().unwrap() - 12.5).abs() < 1e-12);
    }
}
