//! Minimal JSON parser + writer (offline build: no serde facade available).
//!
//! Parses the subset produced by `python/compile/aot.py` for
//! `artifacts/manifest.json` — which is in fact full JSON minus exotic
//! escapes — and writes the JSONL run logs.

use std::collections::HashMap;
use std::fmt::Write as _;

use anyhow::{bail, Result};

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(HashMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing characters at byte {}", p.pos);
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Result<&HashMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("expected object, got {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => bail!("expected array, got {self:?}"),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_f64()? as usize)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("expected bool, got {self:?}"),
        }
    }

    /// Required object field.
    pub fn field<'a>(&'a self, key: &str) -> Result<&'a Json> {
        self.get(key).ok_or_else(|| anyhow::anyhow!("missing field '{key}'"))
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            bail!("expected '{}' at byte {}", c as char, self.pos)
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {:?} at byte {}", other.map(|c| c as char), self.pos),
        }
    }

    fn literal(&mut self, text: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(text.as_bytes()) {
            self.pos += text.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.pos)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = HashMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => bail!("expected ',' or '}}' at byte {}", self.pos),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut arr = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(arr));
        }
        loop {
            arr.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(arr));
                }
                _ => bail!("expected ',' or ']' at byte {}", self.pos),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(
                                &self.bytes[self.pos + 1..self.pos + 5],
                            )?;
                            let code = u32::from_str_radix(hex, 16)?;
                            out.push(char::from_u32(code).unwrap_or('?'));
                            self.pos += 4;
                        }
                        other => bail!("bad escape {other:?}"),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    let start = self.pos;
                    while !matches!(self.peek(), Some(b'"' | b'\\') | None) {
                        self.pos += 1;
                    }
                    out.push_str(std::str::from_utf8(&self.bytes[start..self.pos])?);
                }
                None => bail!("unterminated string"),
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while matches!(self.peek(),
                       Some(b'-' | b'+' | b'.' | b'e' | b'E') | Some(b'0'..=b'9'))
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(text.parse()?))
    }
}

/// Escape a string for JSON output.
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shape() {
        let text = r#"{
          "pad_block": 1024,
          "models": {"test": {"vocab": 128, "artifacts": {"eval": "e.hlo.txt"},
                     "params": [{"name": "w", "shape": [2, 3]}]}},
          "neg": -1.5e2, "flag": true, "none": null
        }"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.field("pad_block").unwrap().as_usize().unwrap(), 1024);
        let model = v.field("models").unwrap().field("test").unwrap();
        assert_eq!(model.field("vocab").unwrap().as_usize().unwrap(), 128);
        let params = model.field("params").unwrap().as_arr().unwrap();
        assert_eq!(params[0].field("shape").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(v.field("neg").unwrap().as_f64().unwrap(), -150.0);
        assert_eq!(v.field("flag").unwrap(), &Json::Bool(true));
        assert_eq!(v.field("none").unwrap(), &Json::Null);
    }

    #[test]
    fn string_escapes() {
        let v = Json::parse(r#""a\nb\t\"c\" A""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\nb\t\"c\" A");
        assert_eq!(escape("x\"y\nz"), "x\\\"y\\nz");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1, 2,]").is_err());
        assert!(Json::parse("{}extra").is_err());
        assert!(Json::parse("{'single': 1}").is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(HashMap::new()));
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
    }

    #[test]
    fn nested_arrays() {
        let v = Json::parse("[[1, 2], [3]]").unwrap();
        let outer = v.as_arr().unwrap();
        assert_eq!(outer[0].as_arr().unwrap().len(), 2);
        assert_eq!(outer[1].as_arr().unwrap()[0].as_f64().unwrap(), 3.0);
    }
}
