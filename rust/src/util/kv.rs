//! Flat `key = value` config files (the `configs/*.toml` format).
//!
//! A pragmatic TOML subset: one `key = value` per line, `#` comments,
//! quoted strings, integers, floats, booleans. A `[section]` header whose
//! name is a bare dotted identifier (e.g. `[parallel]`) namespaces the
//! keys after it as `section.key` — the psyche-style run-config shape;
//! any other bracketed line is ignored for backward compatibility. No
//! arrays — the TrainConfig schema is flat by design.

use std::collections::HashMap;

use anyhow::{bail, Result};

/// True for `[parallel]`-style headers: bare dotted identifiers only.
fn section_name(line: &str) -> Option<&str> {
    let inner = line.strip_prefix('[')?.strip_suffix(']')?.trim();
    let ok = !inner.is_empty()
        && inner
            .chars()
            .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '.' || c == '-');
    ok.then_some(inner)
}

/// Parsed key→raw-value map (section keys are `section.key`).
#[derive(Clone, Debug, Default)]
pub struct KvFile {
    pub entries: HashMap<String, String>,
    /// Identifier `[section]` headers seen, even when empty — a bare
    /// `[parallel]` must still opt a run into the engine defaults.
    pub sections: Vec<String>,
}

impl KvFile {
    pub fn parse(text: &str) -> Result<KvFile> {
        let mut entries = HashMap::new();
        let mut sections = Vec::new();
        let mut prefix = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            if line.starts_with('[') {
                // Strip a trailing comment so `[parallel]  # engine` still
                // opens the section rather than silently resetting to the
                // top level (which would leak its keys past readers).
                let header = match line.find('#') {
                    Some(idx) => line[..idx].trim_end(),
                    None => line,
                };
                prefix = match section_name(header) {
                    Some(name) => {
                        if !sections.iter().any(|s| s == name) {
                            sections.push(name.to_string());
                        }
                        format!("{name}.")
                    }
                    None => String::new(),
                };
                continue;
            }
            let Some((key, value)) = line.split_once('=') else {
                bail!("line {}: expected 'key = value', got '{raw}'", lineno + 1);
            };
            let key = format!("{prefix}{}", key.trim());
            let mut value = value.trim();
            // Strip trailing comments: after the closing quote for quoted
            // values (a '#' inside the quotes is data), anywhere for bare
            // values.
            if let Some(rest) = value.strip_prefix('"') {
                if let Some(end) = rest.find('"') {
                    value = &value[..end + 2];
                }
            } else if let Some(idx) = value.find('#') {
                value = value[..idx].trim();
            }
            let value = if value.starts_with('"') && value.ends_with('"') && value.len() >= 2 {
                value[1..value.len() - 1].to_string()
            } else {
                value.to_string()
            };
            entries.insert(key, value);
        }
        Ok(KvFile { entries, sections })
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.entries.get(key).map(|s| s.as_str())
    }

    pub fn get_u64(&self, key: &str) -> Result<Option<u64>> {
        self.get(key).map(|v| v.parse().map_err(|e| anyhow::anyhow!("{key}: {e}"))).transpose()
    }

    pub fn get_f64(&self, key: &str) -> Result<Option<f64>> {
        self.get(key).map(|v| v.parse().map_err(|e| anyhow::anyhow!("{key}: {e}"))).transpose()
    }

    pub fn get_bool(&self, key: &str) -> Result<Option<bool>> {
        self.get(key).map(|v| v.parse().map_err(|e| anyhow::anyhow!("{key}: {e}"))).transpose()
    }

    /// True if the `[section]` header appeared (even empty) or any key
    /// lives under it.
    pub fn has_section(&self, section: &str) -> bool {
        let prefix = format!("{section}.");
        self.sections.iter().any(|s| s == section)
            || self.entries.keys().any(|k| k.starts_with(&prefix))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_mixed_types() {
        let text = r#"
# a comment
model = "tiny"
steps = 500
lr = 1e-3            # inline comment
fused = true
[section headers are ignored]
rho = 0.25
"#;
        let kv = KvFile::parse(text).unwrap();
        assert_eq!(kv.get("model"), Some("tiny"));
        assert_eq!(kv.get_u64("steps").unwrap(), Some(500));
        assert_eq!(kv.get_f64("lr").unwrap(), Some(1e-3));
        assert_eq!(kv.get_bool("fused").unwrap(), Some(true));
        assert_eq!(kv.get_f64("rho").unwrap(), Some(0.25));
        assert_eq!(kv.get("missing"), None);
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(KvFile::parse("just some words").is_err());
    }

    #[test]
    fn bad_number_errors() {
        let kv = KvFile::parse("steps = many").unwrap();
        assert!(kv.get_u64("steps").is_err());
    }

    #[test]
    fn identifier_sections_namespace_keys() {
        let text = r#"
steps = 10
[parallel]           # trailing comments on headers are fine
workers = 4          # data-parallel workers
grad_accum = 8
[not a real section!]
after = 1
"#;
        let kv = KvFile::parse(text).unwrap();
        assert_eq!(kv.get_u64("steps").unwrap(), Some(10));
        assert_eq!(kv.get_u64("parallel.workers").unwrap(), Some(4));
        assert_eq!(kv.get_u64("parallel.grad_accum").unwrap(), Some(8));
        // A non-identifier header resets to the top level (legacy rule).
        assert_eq!(kv.get_u64("after").unwrap(), Some(1));
        assert!(kv.has_section("parallel"));
        assert!(!kv.has_section("workers"));
    }

    #[test]
    fn empty_section_header_is_recorded() {
        let kv = KvFile::parse("[parallel]\n# all defaults\n").unwrap();
        assert!(kv.has_section("parallel"));
        assert!(kv.entries.is_empty());
    }

    #[test]
    fn dotted_subsections_nest_keys() {
        // The `[parallel.compress]` engine section: a dotted identifier
        // header prefixes its keys with the full dotted path, and the
        // parent section remains visible through the key prefix.
        let text = r#"
[parallel]
workers = 4

[parallel.compress]
mode = "split"    # codec assignment
block = 256
"#;
        let kv = KvFile::parse(text).unwrap();
        assert_eq!(kv.get_u64("parallel.workers").unwrap(), Some(4));
        assert_eq!(kv.get("parallel.compress.mode"), Some("split"));
        assert_eq!(kv.get_u64("parallel.compress.block").unwrap(), Some(256));
        // Inline comments after quoted values strip; '#' inside quotes is
        // data.
        let kv = KvFile::parse("name = \"a#b\"   # comment\n").unwrap();
        assert_eq!(kv.get("name"), Some("a#b"));
        assert!(kv.has_section("parallel"));
        assert!(kv.has_section("parallel.compress"));
        // A subsection alone still implies its parent via the key prefix.
        let kv = KvFile::parse("[parallel.compress]\nmode = \"q8\"\n").unwrap();
        assert!(kv.has_section("parallel"));
        assert!(kv.has_section("parallel.compress"));
    }
}
