//! Flat `key = value` config files (the `configs/*.toml` format).
//!
//! A pragmatic TOML subset: one `key = value` per line, `#` comments,
//! quoted strings, integers, floats, booleans. No tables/arrays — the
//! TrainConfig schema is flat by design.

use std::collections::HashMap;

use anyhow::{bail, Result};

/// Parsed key→raw-value map.
#[derive(Clone, Debug, Default)]
pub struct KvFile {
    pub entries: HashMap<String, String>,
}

impl KvFile {
    pub fn parse(text: &str) -> Result<KvFile> {
        let mut entries = HashMap::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') || line.starts_with('[') {
                continue;
            }
            let Some((key, value)) = line.split_once('=') else {
                bail!("line {}: expected 'key = value', got '{raw}'", lineno + 1);
            };
            let key = key.trim().to_string();
            let mut value = value.trim();
            // strip trailing comment on unquoted values
            if !value.starts_with('"') {
                if let Some(idx) = value.find('#') {
                    value = value[..idx].trim();
                }
            }
            let value = if value.starts_with('"') && value.ends_with('"') && value.len() >= 2 {
                value[1..value.len() - 1].to_string()
            } else {
                value.to_string()
            };
            entries.insert(key, value);
        }
        Ok(KvFile { entries })
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.entries.get(key).map(|s| s.as_str())
    }

    pub fn get_u64(&self, key: &str) -> Result<Option<u64>> {
        self.get(key).map(|v| v.parse().map_err(|e| anyhow::anyhow!("{key}: {e}"))).transpose()
    }

    pub fn get_f64(&self, key: &str) -> Result<Option<f64>> {
        self.get(key).map(|v| v.parse().map_err(|e| anyhow::anyhow!("{key}: {e}"))).transpose()
    }

    pub fn get_bool(&self, key: &str) -> Result<Option<bool>> {
        self.get(key).map(|v| v.parse().map_err(|e| anyhow::anyhow!("{key}: {e}"))).transpose()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_mixed_types() {
        let text = r#"
# a comment
model = "tiny"
steps = 500
lr = 1e-3            # inline comment
fused = true
[section headers are ignored]
rho = 0.25
"#;
        let kv = KvFile::parse(text).unwrap();
        assert_eq!(kv.get("model"), Some("tiny"));
        assert_eq!(kv.get_u64("steps").unwrap(), Some(500));
        assert_eq!(kv.get_f64("lr").unwrap(), Some(1e-3));
        assert_eq!(kv.get_bool("fused").unwrap(), Some(true));
        assert_eq!(kv.get_f64("rho").unwrap(), Some(0.25));
        assert_eq!(kv.get("missing"), None);
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(KvFile::parse("just some words").is_err());
    }

    #[test]
    fn bad_number_errors() {
        let kv = KvFile::parse("steps = many").unwrap();
        assert!(kv.get_u64("steps").is_err());
    }
}
