//! In-tree substrates replacing external crates (this build is fully
//! offline: only `xla` and `anyhow` are external dependencies).
//!
//! - [`rng`]: xoshiro256++ PRNG with the distributions the paper's
//!   algorithms need (uniform, range, normal, shuffle).
//! - [`json`]: minimal JSON parser/writer for `artifacts/manifest.json`
//!   and run logs.
//! - [`kv`]: the flat `key = value` config-file format used by
//!   `configs/*.toml`.
//! - [`bench`]: a tiny timing harness for the `benches/` binaries.

pub mod bench;
pub mod json;
pub mod kv;
pub mod rng;

pub use rng::Prng;
