//! xoshiro256++ PRNG (Blackman & Vigna) seeded via SplitMix64.
//!
//! Deterministic, fast, and good enough for subspace sampling, data
//! generation and init — the roles ChaCha8 plays in the reference
//! implementations. Not cryptographic.

/// A seedable PRNG with the distributions used across the crate.
#[derive(Clone, Debug)]
pub struct Prng {
    s: [u64; 4],
    /// Cached second Box–Muller sample.
    normal_spare: Option<f32>,
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Prng {
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = seed;
        let s = [splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm),
                 splitmix64(&mut sm)];
        Prng { s, normal_spare: None }
    }

    /// The raw generator state — the four xoshiro words plus the cached
    /// Box–Muller spare — for checkpointing the stream position.
    pub fn state(&self) -> ([u64; 4], Option<f32>) {
        (self.s, self.normal_spare)
    }

    /// Rebuild a generator at an exact stream position captured by
    /// [`Prng::state`]: the restored stream continues bit-identically.
    pub fn from_state(s: [u64; 4], normal_spare: Option<f32>) -> Self {
        Prng { s, normal_spare }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let [s0, s1, s2, s3] = self.s;
        let result = s0.wrapping_add(s3).rotate_left(23).wrapping_add(s0);
        let t = s1 << 17;
        let mut s2n = s2 ^ s0;
        let mut s3n = s3 ^ s1;
        let s1n = s1 ^ s2n;
        let s0n = s0 ^ s3n;
        s2n ^= t;
        s3n = s3n.rotate_left(45);
        self.s = [s0n, s1n, s2n, s3n];
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform usize in [lo, hi) — hi must be > lo.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi > lo);
        lo + (self.next_u64() % (hi - lo) as u64) as usize
    }

    /// Standard-normal sample (Box–Muller with caching).
    pub fn normal(&mut self) -> f32 {
        if let Some(z) = self.normal_spare.take() {
            return z;
        }
        loop {
            let u1 = self.f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.normal_spare = Some((r * theta.sin()) as f32);
            return (r * theta.cos()) as f32;
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.range(0, i + 1);
            xs.swap(i, j);
        }
    }

    /// Bernoulli(p).
    #[inline]
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Prng::seed_from_u64(42);
        let mut b = Prng::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Prng::seed_from_u64(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut rng = Prng::seed_from_u64(0);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        assert!((sum / 10_000.0 - 0.5).abs() < 0.02);
    }

    #[test]
    fn range_covers_all_values() {
        let mut rng = Prng::seed_from_u64(1);
        let mut seen = [false; 7];
        for _ in 0..500 {
            seen[rng.range(0, 7)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut rng = Prng::seed_from_u64(2);
        let n = 20_000;
        let samples: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let mean = samples.iter().sum::<f32>() / n as f32;
        let var = samples.iter().map(|x| x * x).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn state_roundtrip_continues_bitwise() {
        let mut a = Prng::seed_from_u64(7);
        // Leave a cached normal spare pending so the snapshot covers it.
        let _ = a.normal();
        let (words, spare) = a.state();
        let mut b = Prng::from_state(words, spare);
        assert_eq!(a.normal().to_bits(), b.normal().to_bits());
        for _ in 0..50 {
            assert_eq!(a.next_u64(), b.next_u64());
            assert_eq!(a.normal().to_bits(), b.normal().to_bits());
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Prng::seed_from_u64(3);
        let mut xs: Vec<usize> = (0..50).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>());
    }
}
