//! Steady-state allocation audit for the engine's gradient hot path.
//!
//! The zero-allocation contract (ISSUE 4 tentpole): after warm-up —
//! first step of a round, when pooled buffers take this round's shapes —
//! an `Engine::step` on the **logical-worker** path performs zero heap
//! allocations end to end: batch fill, reference-model forward/backward,
//! leaf encode, tree reduce (decode-combine-reencode), root decode,
//! sharded Adam/signSGD update, and scatter. The threaded path shares
//! every model-scale buffer but additionally pays small `mpsc` channel
//! nodes per message, so the strict zero assertion is pinned on the
//! logical path (the pool-steady-state test in `engine_parallel`
//! covers the threaded one at message granularity).
//!
//! Mechanism: a counting `#[global_allocator]` wrapper over `System`
//! with a *thread-local* enable flag — the logical engine runs entirely
//! on the test thread, so only its allocations are counted, and
//! const-initialized TLS cells make the counter itself allocation-free
//! (no lazy-init recursion inside `alloc`).
//!
//! Telemetry (ISSUE 6) runs INSIDE the pinned region: the span flight
//! recorder is enabled by default (asserted below), so the zero
//! assertions prove the counter accrual and span capture allocate
//! nothing in steady state — counters are plain `u64` adds and spans
//! write into the preallocated ring/histograms.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

use frugal::coordinator::subspace::{MaskBuilder, SubspacePolicy};
use frugal::coordinator::LrSchedule;
use frugal::engine::{
    CompressCfg, CompressMode, Engine, EngineCfg, GradSource, ParallelCfg, RefLm, RefLmCfg,
    Sources,
};
use frugal::optim::adamw::AdamCfg;
use frugal::optim::frugal::BlockPolicy;

thread_local! {
    static ENABLED: Cell<bool> = const { Cell::new(false) };
    static ALLOCS: Cell<u64> = const { Cell::new(0) };
    static REALLOCS: Cell<u64> = const { Cell::new(0) };
}

struct CountingAlloc;

fn bump(counter: &'static std::thread::LocalKey<Cell<u64>>) {
    ENABLED.with(|flag| {
        if flag.get() {
            counter.with(|c| c.set(c.get() + 1));
        }
    });
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        bump(&ALLOCS);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        bump(&ALLOCS);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        bump(&REALLOCS);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static COUNTER: CountingAlloc = CountingAlloc;

const SEED: u64 = 7;
/// One long round so the 40-step warm-up and the 8 measured steps stay
/// inside it (round boundaries are allowed to (re)allocate — shapes
/// change there).
const UPDATE_FREQ: u64 = 64;

fn engine(workers: usize, mode: CompressMode) -> Engine {
    let m = RefLm::new(RefLmCfg::default());
    let layout = m.layout().clone();
    // Logical (non-threaded) workers: everything runs on this thread.
    let sources =
        Sources::Local((0..workers).map(|_| Box::new(m.clone()) as Box<dyn GradSource>).collect());
    let mask_builder =
        MaskBuilder::new(layout, 0.25, SubspacePolicy::Blockwise(BlockPolicy::Random), SEED);
    let cfg = EngineCfg {
        parallel: ParallelCfg {
            workers,
            grad_accum: 4,
            threaded: false,
            compress: CompressCfg { mode, block: 64 },
            ..Default::default()
        },
        schedule: LrSchedule::ConstantWarmup { warmup: 2 },
        peak_lr: 1e-3,
        lr_free_mult: 1.0,
        update_freq: UPDATE_FREQ,
        adam: AdamCfg::default(),
        clip: None,
    };
    Engine::builder()
        .mask_builder(mask_builder)
        .cfg(cfg)
        .sources(sources)
        .init_flat(m.init_flat(SEED))
        .build()
        .unwrap()
}

/// Fill-style batch closure that never allocates: the PRNG is stack-only
/// and the token buffer keeps its capacity across steps.
fn batch_fn(micro: u64, buf: &mut Vec<i32>) {
    let cfg = RefLmCfg::default();
    let mut rng = frugal::util::Prng::seed_from_u64(0xA110C ^ micro.wrapping_mul(0x9E37));
    buf.clear();
    buf.extend((0..cfg.batch * cfg.seq_len).map(|_| rng.range(0, cfg.vocab) as i32));
}

#[test]
fn grad_path_is_allocation_free_after_warmup() {
    for mode in [CompressMode::None, CompressMode::Split] {
        for workers in [1usize, 2] {
            let mut e = engine(workers, mode);
            // The pin must cover telemetry: spans default ON, so the
            // measured steps record every phase into the flight recorder.
            assert!(
                e.telemetry().recorder.enabled(),
                "span recorder must be enabled for this pin to cover telemetry"
            );
            // Warm-up: the round's shapes settle on step 1; the extra
            // steps also grow the metrics log past the next Vec-doubling
            // boundary (40 records -> capacity 64 > 48).
            for _ in 0..40 {
                e.step(&batch_fn).unwrap();
            }
            let pool_before = e.pool_stats();
            ENABLED.with(|flag| flag.set(true));
            ALLOCS.with(|c| c.set(0));
            REALLOCS.with(|c| c.set(0));
            for _ in 0..8 {
                e.step(&batch_fn).unwrap();
            }
            ENABLED.with(|flag| flag.set(false));
            let allocs = ALLOCS.with(|c| c.get());
            let reallocs = REALLOCS.with(|c| c.get());
            let pool_after = e.pool_stats();
            assert_eq!(
                allocs, 0,
                "{mode:?} workers={workers}: {allocs} heap allocations across 8 \
                 steady-state steps"
            );
            assert_eq!(
                reallocs, 0,
                "{mode:?} workers={workers}: {reallocs} reallocations across 8 \
                 steady-state steps"
            );
            assert_eq!(
                pool_after.misses, pool_before.misses,
                "{mode:?} workers={workers}: pool allocated fresh messages mid-round"
            );
            // Sanity: the steps actually ran (pool traffic + loss finite).
            assert!(pool_after.grabs >= pool_before.grabs + 8 * 4);
        }
    }
}

/// Variable-ρ (ISSUE 5): under a per-epoch decaying schedule the lane
/// groups change shape at every round boundary — fresh Adam shards,
/// re-formed codec plans, re-shaped pooled messages. The boundary step
/// is allowed to (re)allocate; every later step of the epoch must be
/// allocation-free again, i.e. the pool steady state re-pins
/// immediately (well inside the two-round budget), and the pool itself
/// never mints new messages (misses stay flat: shrinking/growing lane
/// groups reuse the same recycled buffers).
#[test]
fn variable_rho_re_pins_steady_state_each_epoch() {
    const T: u64 = 6;
    let m = RefLm::new(RefLmCfg::default());
    let layout = m.layout().clone();
    let sources = Sources::Local(
        (0..2).map(|_| Box::new(m.clone()) as Box<dyn GradSource>).collect(),
    );
    let sched = frugal::schedule::RhoSchedule::parse("linear:0.5:0.1:8").unwrap();
    let mask_builder = MaskBuilder::with_schedule(
        layout,
        sched,
        SubspacePolicy::Blockwise(BlockPolicy::Random),
        SEED,
    );
    let cfg = EngineCfg {
        parallel: ParallelCfg {
            workers: 2,
            grad_accum: 4,
            threaded: false,
            compress: CompressCfg { mode: CompressMode::Split, block: 64 },
            ..Default::default()
        },
        schedule: LrSchedule::ConstantWarmup { warmup: 2 },
        peak_lr: 1e-3,
        lr_free_mult: 1.0,
        update_freq: T,
        adam: AdamCfg::default(),
        clip: None,
    };
    let mut e = Engine::builder()
        .mask_builder(mask_builder)
        .cfg(cfg)
        .sources(sources)
        .init_flat(m.init_flat(SEED))
        .build()
        .unwrap();
    // Warm-up: rounds 1-6 (36 steps). ρ has already decayed four times
    // by then, and the metrics log is past its next Vec-doubling
    // boundary (capacity 64 covers the 48 steps this test runs).
    for _ in 0..36 {
        e.step(&batch_fn).unwrap();
    }
    let misses_before = e.pool_stats().misses;
    for round in [7u64, 8] {
        // K changes on this boundary step — (re)allocation allowed here.
        e.step(&batch_fn).unwrap();
        // Every remaining step of the epoch: zero heap traffic.
        ENABLED.with(|flag| flag.set(true));
        ALLOCS.with(|c| c.set(0));
        REALLOCS.with(|c| c.set(0));
        for _ in 1..T {
            e.step(&batch_fn).unwrap();
        }
        ENABLED.with(|flag| flag.set(false));
        let allocs = ALLOCS.with(|c| c.get());
        let reallocs = REALLOCS.with(|c| c.get());
        assert_eq!(
            allocs, 0,
            "round {round}: {allocs} allocations after the epoch's re-provisioning step"
        );
        assert_eq!(
            reallocs, 0,
            "round {round}: {reallocs} reallocations after the epoch's re-provisioning step"
        );
    }
    // The pool never minted a new message across two K changes: every
    // reshaped buffer was a recycled one.
    assert_eq!(
        e.pool_stats().misses,
        misses_before,
        "variable-rho rounds forced fresh pool messages"
    );
    assert_eq!(e.round(), 8);
}

/// Streaming data plane (ISSUE 8): with a shard corpus behind the
/// prefetch ring, the consumer side of `Prefetcher::fill` — ring pop,
/// buffer swap, recycle — is allocation-free once capacities are warm.
/// The producer thread does the shard I/O, but it is a *different*
/// thread, invisible to this thread-local pin by construction; what the
/// pin proves is that the engine's hot loop stays zero-allocation when
/// its batches come off disk instead of a PRNG.
#[test]
fn streaming_prefetch_consumer_is_allocation_free_after_warmup() {
    use std::sync::Arc;

    use frugal::data::stream::{pack_corpus, Prefetcher, StreamingCorpus};
    use frugal::data::Corpus;

    let mcfg = RefLmCfg::default();
    let dir = std::env::temp_dir()
        .join(format!("frugal_alloc_stream_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let mut rng = frugal::util::Prng::seed_from_u64(0xA110C);
    let tokens: Vec<i32> =
        (0..96 * mcfg.seq_len).map(|_| rng.range(0, mcfg.vocab) as i32).collect();
    pack_corpus(&dir, mcfg.seq_len, mcfg.vocab, 32, &tokens).unwrap();
    let corpus =
        Arc::new(StreamingCorpus::open(&dir, mcfg.batch, SEED).unwrap()) as Arc<dyn Corpus>;
    let pf = Prefetcher::new(Arc::clone(&corpus), 8, 0);
    let stream_fn = |micro: u64, buf: &mut Vec<i32>| pf.fill(micro, buf);

    let mut e = engine(2, CompressMode::Split);
    for _ in 0..40 {
        e.step(&stream_fn).unwrap();
    }
    ENABLED.with(|flag| flag.set(true));
    ALLOCS.with(|c| c.set(0));
    REALLOCS.with(|c| c.set(0));
    for _ in 0..8 {
        e.step(&stream_fn).unwrap();
    }
    ENABLED.with(|flag| flag.set(false));
    let allocs = ALLOCS.with(|c| c.get());
    let reallocs = REALLOCS.with(|c| c.get());
    assert_eq!(
        allocs, 0,
        "streaming+prefetch: {allocs} heap allocations across 8 steady-state steps"
    );
    assert_eq!(
        reallocs, 0,
        "streaming+prefetch: {reallocs} reallocations across 8 steady-state steps"
    );
    drop(pf);
    std::fs::remove_dir_all(&dir).ok();
}
