//! Integration tests for the sharded checkpoint/resume subsystem.
//!
//! The headline invariant (ISSUE 3 acceptance): train 2K steps ≡ train K
//! steps, save, kill, resume K steps — a **bitwise-identical** loss trace
//! and final parameter vector, across worker counts (snapshots are
//! lane-keyed, so a `workers=4` snapshot restores at `workers=2`) and
//! under both `--compress none` and `split`. Round-barrier snapshots are
//! bit-exact under either moment codec (state resets there anyway);
//! mid-round snapshots are bit-exact under `raw`.

use std::path::PathBuf;

use frugal::ckpt::{self, MomentCodec, SaveOptions};
use frugal::coordinator::subspace::{MaskBuilder, SubspacePolicy};
use frugal::coordinator::LrSchedule;
use frugal::engine::{
    CompressCfg, CompressMode, Engine, EngineCfg, GradSource, ParallelCfg, RefLm, RefLmCfg,
    Sources,
};
use frugal::optim::adamw::AdamCfg;
use frugal::optim::frugal::BlockPolicy;

const SEED: u64 = 42;
const UPDATE_FREQ: u64 = 4;
const GRAD_ACCUM: usize = 4;

fn model() -> RefLm {
    RefLm::new(RefLmCfg::default())
}

fn engine(workers: usize, mode: CompressMode) -> Engine {
    engine_cfg(workers, mode, GRAD_ACCUM, UPDATE_FREQ)
}

fn engine_cfg(workers: usize, mode: CompressMode, grad_accum: usize, update_freq: u64) -> Engine {
    let m = model();
    let layout = m.layout().clone();
    let sources = Sources::Threaded(
        (0..workers).map(|_| Box::new(m.clone()) as Box<dyn GradSource + Send>).collect(),
    );
    let mask_builder = MaskBuilder::new(
        layout,
        0.25,
        SubspacePolicy::Blockwise(BlockPolicy::Random),
        SEED,
    );
    let cfg = EngineCfg {
        parallel: ParallelCfg {
            workers,
            grad_accum,
            compress: CompressCfg { mode, block: 64 },
            ..Default::default()
        },
        schedule: LrSchedule::ConstantWarmup { warmup: 2 },
        peak_lr: 1e-3,
        lr_free_mult: 1.0,
        update_freq,
        adam: AdamCfg::default(),
        clip: None,
    };
    Engine::builder()
        .mask_builder(mask_builder)
        .cfg(cfg)
        .sources(sources)
        .init_flat(m.init_flat(SEED))
        .build()
        .unwrap()
}

fn batch_fn(micro: u64, buf: &mut Vec<i32>) {
    let cfg = RefLmCfg::default();
    let mut rng = frugal::util::Prng::seed_from_u64(0xC4A7 ^ micro.wrapping_mul(0x9E37));
    buf.clear();
    buf.extend((0..cfg.batch * cfg.seq_len).map(|_| rng.range(0, cfg.vocab) as i32));
}

fn run(engine: &mut Engine, steps: u64) -> Vec<u32> {
    (0..steps).map(|_| engine.step(&batch_fn).unwrap().to_bits()).collect()
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

fn tmpdir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("frugal_resume_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

/// Save at step K, restore into a fresh engine with `resume_workers`,
/// run the remaining steps, and return (trace_bits, flat_bits).
fn interrupt_and_resume(
    save_workers: usize,
    resume_workers: usize,
    mode: CompressMode,
    k: u64,
    remaining: u64,
    codec: MomentCodec,
    tag: &str,
) -> (Vec<u32>, Vec<u32>) {
    let mut first = engine(save_workers, mode);
    let mut trace = run(&mut first, k);
    let dir = tmpdir(tag);
    ckpt::save(&dir, &first.capture_state().unwrap(), SaveOptions::new(codec, 64)).unwrap();
    drop(first); // the "kill"
    let mut resumed = engine(resume_workers, mode);
    resumed.restore_state(ckpt::load(&dir).unwrap()).unwrap();
    assert_eq!(resumed.global_step(), k);
    trace.extend(run(&mut resumed, remaining));
    std::fs::remove_dir_all(&dir).ok();
    (trace, bits(&resumed.flat))
}

/// The acceptance criterion: a q8 snapshot taken at a round barrier
/// (K = 2 rounds at T=4) resumes bitwise — trace and parameters — for
/// compress none and split, with the snapshot taken at workers=4 and
/// restored at workers=2 and 1 (elastic re-sharding), all against the
/// uninterrupted workers=1 run.
#[test]
fn resume_at_round_barrier_is_bitwise_q8() {
    for mode in [CompressMode::None, CompressMode::Split] {
        let mut continuous = engine(1, mode);
        let want_trace = run(&mut continuous, 16);
        let want_flat = bits(&continuous.flat);
        for resume_workers in [1usize, 2, 4] {
            let (trace, flat) = interrupt_and_resume(
                4,
                resume_workers,
                mode,
                8,
                8,
                MomentCodec::Q8,
                &format!("barrier_{mode}_{resume_workers}"),
            );
            assert_eq!(trace, want_trace, "{mode:?} -> workers={resume_workers}");
            assert_eq!(flat, want_flat, "{mode:?} -> workers={resume_workers}");
        }
    }
}

/// Barrier-save elision end-to-end: a snapshot taken at a round barrier
/// with the production options writes NO shard files (Adam moments and
/// EF residuals are provably discarded by the resumed run's first step),
/// and the resumed run still bitwise-matches the continuous one — trace
/// and parameters — for compress none and split.
#[test]
fn barrier_elided_snapshot_resumes_bitwise() {
    for mode in [CompressMode::None, CompressMode::Split] {
        let mut continuous = engine(1, mode);
        let want_trace = run(&mut continuous, 16);
        let want_flat = bits(&continuous.flat);

        let mut first = engine(2, mode);
        let mut trace = run(&mut first, 8); // step 8 = barrier at T=4
        let dir = tmpdir(&format!("elide_{mode}"));
        ckpt::save(
            &dir,
            &first.capture_state().unwrap(),
            SaveOptions::new(MomentCodec::Q8, 64),
        )
        .unwrap();
        drop(first);
        // The elision actually happened: manifest flagged, no shards.
        let man = ckpt::CkptManifest::read(&dir).unwrap();
        assert!(man.barrier, "{mode:?}: barrier save not elided");
        assert!(man.shards.is_empty());
        assert!(!dir.join("shard_0000.bin").exists());

        let mut resumed = engine(4, mode);
        resumed.restore_state(ckpt::load(&dir).unwrap()).unwrap();
        trace.extend(run(&mut resumed, 8));
        assert_eq!(trace, want_trace, "{mode:?}: elided resume diverged");
        assert_eq!(bits(&resumed.flat), want_flat, "{mode:?}");
        std::fs::remove_dir_all(&dir).ok();
    }
}

/// Mid-round snapshots (K=6 at T=4: two steps into round 2, live Adam
/// moments and EF residuals) are bit-exact under the raw moment codec,
/// including across worker counts.
#[test]
fn mid_round_resume_is_bitwise_raw() {
    for mode in [CompressMode::None, CompressMode::Split] {
        let mut continuous = engine(1, mode);
        let want_trace = run(&mut continuous, 10);
        let want_flat = bits(&continuous.flat);
        for (save_w, resume_w) in [(4usize, 2usize), (2, 3), (1, 4)] {
            let (trace, flat) = interrupt_and_resume(
                save_w,
                resume_w,
                mode,
                6,
                4,
                MomentCodec::Raw,
                &format!("midround_{mode}_{save_w}_{resume_w}"),
            );
            assert_eq!(trace, want_trace, "{mode:?} {save_w}->{resume_w}");
            assert_eq!(flat, want_flat, "{mode:?} {save_w}->{resume_w}");
        }
    }
}

/// A mid-round q8 snapshot still resumes (documented as approximate):
/// same step accounting, finite losses, close-but-not-necessarily-equal
/// trace.
#[test]
fn mid_round_q8_resume_runs_and_stays_close() {
    let mut continuous = engine(1, CompressMode::None);
    let want: Vec<f32> = (0..10).map(|_| continuous.step(&batch_fn).unwrap()).collect();
    let (trace, _) =
        interrupt_and_resume(2, 2, CompressMode::None, 6, 4, MomentCodec::Q8, "midq8");
    let got: Vec<f32> = trace.iter().map(|&b| f32::from_bits(b)).collect();
    // First 6 steps are pre-save and exactly shared.
    for (i, (&g, &w)) in got.iter().zip(&want).take(6).enumerate() {
        assert_eq!(g.to_bits(), w.to_bits(), "pre-save step {i}");
    }
    for (i, (&g, &w)) in got.iter().zip(&want).enumerate().skip(6) {
        assert!(g.is_finite(), "step {i} diverged");
        assert!((g - w).abs() / w.abs() < 0.05, "step {i}: {g} vs {w}");
    }
}

/// Engine-level proptest sweep: across random shapes the captured state
/// survives save/load bit-exactly under raw, from several round phases.
#[test]
fn prop_engine_capture_roundtrips_through_disk() {
    for case in 0..6u64 {
        let workers = 1 + (case as usize % 4);
        let grad_accum = 1 + (case as usize % 5);
        let update_freq = 2 + (case % 5);
        let mode = if case % 2 == 0 { CompressMode::Split } else { CompressMode::Q8 };
        let mut e = engine_cfg(workers, mode, grad_accum, update_freq);
        run(&mut e, 1 + case);
        let st = e.capture_state().unwrap();
        let dir = tmpdir(&format!("prop{case}"));
        ckpt::save(&dir, &st, SaveOptions::exact(MomentCodec::Raw, 32)).unwrap();
        let back = ckpt::load(&dir).unwrap();
        assert_eq!(bits(&back.flat), bits(&st.flat), "case {case}");
        assert_eq!(bits(&back.m), bits(&st.m), "case {case}");
        assert_eq!(bits(&back.v), bits(&st.v), "case {case}");
        assert_eq!(back.full_lanes, st.full_lanes, "case {case}");
        assert_eq!(back.residuals.len(), st.residuals.len(), "case {case}");
        assert_eq!(back.step, st.step);
        std::fs::remove_dir_all(&dir).ok();
    }
}

/// Corruption anywhere in the snapshot is rejected by the CRC/validation
/// layers: flipped bytes, truncation, missing files, garbage manifests.
#[test]
fn corrupted_snapshots_are_rejected() {
    let mut e = engine(2, CompressMode::Split);
    run(&mut e, 3);
    let dir = tmpdir("corrupt");
    ckpt::save(&dir, &e.capture_state().unwrap(), SaveOptions::new(MomentCodec::Q8, 64)).unwrap();
    assert!(ckpt::load(&dir).is_ok());

    let corrupt_one = |file: &str, f: &dyn Fn(Vec<u8>) -> Vec<u8>| {
        let path = dir.join(file);
        let orig = std::fs::read(&path).unwrap();
        std::fs::write(&path, f(orig.clone())).unwrap();
        let err = ckpt::load(&dir);
        std::fs::write(&path, orig).unwrap();
        assert!(err.is_err(), "{file} corruption not caught");
    };
    // Bit flip mid-file (payload -> section CRC; header -> parse error).
    corrupt_one("shard_0000.bin", &|mut b| {
        let n = b.len();
        b[n / 2] ^= 0x10;
        b
    });
    corrupt_one("meta.bin", &|mut b| {
        let n = b.len();
        b[n - 3] ^= 0x01;
        b
    });
    // Truncation and trailing garbage.
    corrupt_one("shard_0001.bin", &|b| b[..b.len() - 7].to_vec());
    corrupt_one("meta.bin", &|mut b| {
        b.push(0xEE);
        b
    });
    // Manifest: garbage text, wrong format marker, path traversal.
    corrupt_one("manifest.json", &|_| b"{\"format\": \"nope\"}".to_vec());
    corrupt_one("manifest.json", &|_| b"garbage".to_vec());
    corrupt_one("manifest.json", &|b| {
        String::from_utf8(b).unwrap().replace("meta.bin", "../meta.bin").into_bytes()
    });
    // A missing shard file.
    let gone = dir.join("shard_0001.bin");
    let orig = std::fs::read(&gone).unwrap();
    std::fs::remove_file(&gone).unwrap();
    assert!(ckpt::load(&dir).is_err(), "missing shard not caught");
    std::fs::write(&gone, orig).unwrap();
    assert!(ckpt::load(&dir).is_ok(), "restored snapshot should load again");
    std::fs::remove_dir_all(&dir).ok();
}

/// Restore refuses run shapes that change the math (grad_accum /
/// update_freq), refuses non-fresh engines, and capture refuses a
/// stepless engine.
#[test]
fn restore_and_capture_guard_rails() {
    let fresh = engine(1, CompressMode::None);
    assert!(fresh.capture_state().is_err(), "capture before step 1 must fail");

    let mut e = engine(2, CompressMode::None);
    run(&mut e, 4);
    let st = e.capture_state().unwrap();

    let mut wrong_accum = engine_cfg(2, CompressMode::None, GRAD_ACCUM + 1, UPDATE_FREQ);
    let err = wrong_accum.restore_state(st.clone()).unwrap_err();
    assert!(format!("{err}").contains("grad_accum"), "{err}");

    let mut wrong_freq = engine_cfg(2, CompressMode::None, GRAD_ACCUM, UPDATE_FREQ + 1);
    let err = wrong_freq.restore_state(st.clone()).unwrap_err();
    assert!(format!("{err}").contains("update_freq"), "{err}");

    // A different subspace-selection rule (rho/policy) must be rejected:
    // the masks would silently diverge at the next re-selection.
    let mut tampered = st.clone();
    tampered.subspace = "rho=0.5 policy=Columnwise".into();
    let mut wrong_rule = engine(2, CompressMode::None);
    let err = wrong_rule.restore_state(tampered).unwrap_err();
    assert!(format!("{err}").contains("subspace selection"), "{err}");

    let mut not_fresh = engine(2, CompressMode::None);
    run(&mut not_fresh, 1);
    let err = not_fresh.restore_state(st).unwrap_err();
    assert!(format!("{err}").contains("fresh engine"), "{err}");
}

/// Wire-byte counters and round/report accounting stay continuous
/// across a resume.
#[test]
fn counters_and_rounds_continue_across_resume() {
    let mut continuous = engine(1, CompressMode::Split);
    run(&mut continuous, 12);

    let mut first = engine(1, CompressMode::Split);
    run(&mut first, 8);
    let dir = tmpdir("counters");
    ckpt::save(&dir, &first.capture_state().unwrap(), SaveOptions::new(MomentCodec::Q8, 64))
        .unwrap();
    let mut resumed = engine(1, CompressMode::Split);
    resumed.restore_state(ckpt::load(&dir).unwrap()).unwrap();
    run(&mut resumed, 4);

    assert_eq!(resumed.global_step(), continuous.global_step());
    assert_eq!(resumed.round(), continuous.round());
    assert_eq!(resumed.wire_stats().bytes, continuous.wire_stats().bytes);
    assert_eq!(resumed.wire_stats().dense_bytes, continuous.wire_stats().dense_bytes);
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------------------
// Mid-schedule restarts (variable-ρ) + resume-time fingerprints
// ---------------------------------------------------------------------------

/// Like [`engine_cfg`] but with a variable-ρ schedule driving the mask
/// builder (same RNG stream, per-epoch target widths).
fn engine_sched(
    workers: usize,
    mode: CompressMode,
    spec: &str,
) -> Engine {
    let m = model();
    let layout = m.layout().clone();
    let sources = Sources::Threaded(
        (0..workers).map(|_| Box::new(m.clone()) as Box<dyn GradSource + Send>).collect(),
    );
    let sched = frugal::schedule::RhoSchedule::parse(spec).unwrap();
    let mask_builder = MaskBuilder::with_schedule(
        layout,
        sched,
        SubspacePolicy::Blockwise(BlockPolicy::Random),
        SEED,
    );
    let cfg = EngineCfg {
        parallel: ParallelCfg {
            workers,
            grad_accum: GRAD_ACCUM,
            compress: CompressCfg { mode, block: 64 },
            ..Default::default()
        },
        schedule: LrSchedule::ConstantWarmup { warmup: 2 },
        peak_lr: 1e-3,
        lr_free_mult: 1.0,
        update_freq: UPDATE_FREQ,
        adam: AdamCfg::default(),
        clip: None,
    };
    Engine::builder()
        .mask_builder(mask_builder)
        .cfg(cfg)
        .sources(sources)
        .init_flat(m.init_flat(SEED))
        .build()
        .unwrap()
}

/// A 2-step decay at T=4: epochs 0-1 run rho 0.4, epochs 2+ run 0.2
/// (steps 1-8 vs 9-16) — the save points below sit exactly on and just
/// after the ρ-change boundary.
const SCHED: &str = "step:0.4:0.5:2:0.05";

/// Acceptance criterion, checkpoint half: a snapshot saved AT the
/// epoch boundary where ρ drops (step 8, a round barrier — the save is
/// barrier-elided) resumes bitwise across the ρ decrease, at workers
/// 4 → 2 and 4 → 1, for compress none and split, against the
/// uninterrupted workers=1 run. The resumed run's first step performs
/// the K-shrinking re-provisioning from restored RNG state alone.
#[test]
fn mid_schedule_save_at_epoch_boundary_resumes_bitwise() {
    for mode in [CompressMode::None, CompressMode::Split] {
        let mut continuous = engine_sched(1, mode, SCHED);
        let want_trace = run(&mut continuous, 16);
        let want_flat = bits(&continuous.flat);

        let mut first = engine_sched(4, mode, SCHED);
        let trace = run(&mut first, 8); // step 8: barrier AND rho boundary
        let dir = tmpdir(&format!("sched_barrier_{mode}"));
        ckpt::save(&dir, &first.capture_state().unwrap(), SaveOptions::new(MomentCodec::Q8, 64))
            .unwrap();
        drop(first); // the kill
        // The boundary save is barrier-elided; the manifest records the
        // (pre-drop) epoch's rho and the layout fingerprint.
        let man = ckpt::CkptManifest::read(&dir).unwrap();
        assert!(man.barrier, "{mode:?}: boundary save should elide");
        assert!((man.rho - 0.4).abs() < 1e-6, "{mode:?}: manifest rho {}", man.rho);
        assert!(!man.layout.is_empty(), "{mode:?}: manifest must carry a layout fingerprint");
        assert!(man.subspace.contains(SCHED), "{mode:?}: schedule not in fingerprint");

        for resume_workers in [2usize, 1] {
            let mut resumed = engine_sched(resume_workers, mode, SCHED);
            resumed.restore_state(ckpt::load(&dir).unwrap()).unwrap();
            let tail = run(&mut resumed, 8);
            let mut full = trace.clone();
            full.extend(tail);
            assert_eq!(full, want_trace, "{mode:?} -> workers={resume_workers}");
            assert_eq!(bits(&resumed.flat), want_flat, "{mode:?} -> workers={resume_workers}");
            // The resumed run really did shrink: epoch 2+ reports run at
            // the decayed density.
            let last = resumed.reports().last().unwrap();
            assert!((last.rho - 0.2).abs() < 1e-6, "{mode:?}: resumed rho {}", last.rho);
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}

/// Mid-epoch kill AFTER the ρ decrease (step 10, two steps into the
/// rho-0.2 epoch: live Adam moments over the shrunken lane set, live EF
/// residuals): bit-exact under the raw moment codec at workers 4 → 2,
/// for compress none and split.
#[test]
fn mid_schedule_mid_epoch_resume_is_bitwise_raw() {
    for mode in [CompressMode::None, CompressMode::Split] {
        let mut continuous = engine_sched(1, mode, SCHED);
        let want_trace = run(&mut continuous, 16);
        let want_flat = bits(&continuous.flat);

        let mut first = engine_sched(4, mode, SCHED);
        let mut trace = run(&mut first, 10); // mid-epoch, post-decrease
        let dir = tmpdir(&format!("sched_mid_{mode}"));
        ckpt::save(&dir, &first.capture_state().unwrap(), SaveOptions::new(MomentCodec::Raw, 64))
            .unwrap();
        drop(first);
        let man = ckpt::CkptManifest::read(&dir).unwrap();
        assert!(!man.barrier);
        assert!((man.rho - 0.2).abs() < 1e-6, "{mode:?}: manifest rho {}", man.rho);

        let mut resumed = engine_sched(2, mode, SCHED);
        resumed.restore_state(ckpt::load(&dir).unwrap()).unwrap();
        assert_eq!(resumed.global_step(), 10);
        trace.extend(run(&mut resumed, 6));
        assert_eq!(trace, want_trace, "{mode:?}");
        assert_eq!(bits(&resumed.flat), want_flat, "{mode:?}");
        std::fs::remove_dir_all(&dir).ok();
    }
}

/// Resume-time fingerprints: each mismatch class is rejected with its
/// own clear error — (a) a different model shape fails on the LAYOUT
/// fingerprint (not a downstream lane-count message), (b) a different
/// ρ-schedule fails on the subspace fingerprint, (c) a different wire
/// codec fails on the codec check.
#[test]
fn resume_fingerprints_reject_shape_rho_and_codec_mismatches() {
    let mut e = engine(2, CompressMode::None);
    run(&mut e, 4);
    let st = e.capture_state().unwrap();

    // (a) Shape mismatch: a wider reference model. The layout check
    // must fire first — its message names the layout fingerprints.
    let big = RefLm::new(RefLmCfg { d_model: 24, ..RefLmCfg::default() });
    let sources = Sources::Threaded(vec![
        Box::new(big.clone()) as Box<dyn GradSource + Send>,
    ]);
    let mask_builder = MaskBuilder::new(
        big.layout().clone(),
        0.25,
        SubspacePolicy::Blockwise(BlockPolicy::Random),
        SEED,
    );
    let cfg = EngineCfg {
        parallel: ParallelCfg { workers: 1, grad_accum: GRAD_ACCUM, ..Default::default() },
        schedule: LrSchedule::ConstantWarmup { warmup: 2 },
        peak_lr: 1e-3,
        lr_free_mult: 1.0,
        update_freq: UPDATE_FREQ,
        adam: AdamCfg::default(),
        clip: None,
    };
    let mut wrong_shape = Engine::builder()
        .mask_builder(mask_builder)
        .cfg(cfg)
        .sources(sources)
        .init_flat(big.init_flat(SEED))
        .build()
        .unwrap();
    let err = wrong_shape.restore_state(st.clone()).unwrap_err();
    let msg = format!("{err}");
    assert!(msg.contains("model layout"), "want the layout diagnosis, got: {msg}");

    // (b) ρ-schedule mismatch: same model, different schedule — the
    // masks would silently diverge at the next re-selection.
    let mut wrong_sched = engine_sched(2, CompressMode::None, "linear:0.4:0.1:4");
    let err = wrong_sched.restore_state(st.clone()).unwrap_err();
    assert!(format!("{err}").contains("subspace selection"), "{err}");

    // (c) Wire-codec mismatch: the transported bits differ, so resume
    // under a different --compress is rejected, not merely noted.
    let mut wrong_codec = engine(2, CompressMode::Split);
    let err = wrong_codec.restore_state(st).unwrap_err();
    assert!(format!("{err}").contains("--compress"), "{err}");
}
