//! Integration tests for the streaming data plane + batch-size warmup.
//!
//! The headline invariants, at the engine level:
//!
//! - a packed shard corpus feeds the engine through the same fill-style
//!   contract as the synthetic corpus, and `workers 1 ≡ workers 2` stays
//!   bitwise (loss trace and parameters) on streamed data, with and
//!   without the prefetch pipeline in front;
//! - a linear batch warmup is a pure function of the round counter:
//!   kill/resume mid-warmup reproduces the continuous run bitwise at
//!   workers 1/2/4, and the schedule composes with a variable-ρ mask
//!   schedule (both re-provision at the same round boundary);
//! - the canonical schedule string is a checkpoint fingerprint: a resume
//!   under a different (or missing) batch schedule is rejected up front;
//! - the data server serves bit-identical batches to a local open of the
//!   same shard directory.

use std::path::PathBuf;
use std::sync::Arc;

use frugal::ckpt::{self, MomentCodec, SaveOptions};
use frugal::coordinator::subspace::{MaskBuilder, SubspacePolicy};
use frugal::coordinator::LrSchedule;
use frugal::data::stream::{
    pack_corpus, read_shard_verified, DataIndex, DataServer, Prefetcher, RemoteCorpus,
    StreamingCorpus,
};
use frugal::data::{Corpus, CorpusConfig, SyntheticCorpus, SyntheticStream};
use frugal::engine::transport::{default_addr, TransportKind};
use frugal::engine::{Engine, EngineCfg, GradSource, ParallelCfg, RefLm, RefLmCfg, Sources};
use frugal::optim::adamw::AdamCfg;
use frugal::optim::frugal::BlockPolicy;
use frugal::schedule::{BatchPlan, BatchSchedule, RhoSchedule};
use frugal::telemetry::Counter;
use frugal::util::Prng;

const SEED: u64 = 42;
/// RefLm default geometry: 4 seqs × 16 tokens per micro-batch.
const TOKENS_PER_MICRO: u64 = 64;
const UPDATE_FREQ: u64 = 4;

fn tmpdir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("frugal_dstream_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Pack a small deterministic corpus matching the RefLm geometry.
fn packed_dir(tag: &str, n_seqs: usize) -> PathBuf {
    let cfg = RefLmCfg::default();
    let dir = tmpdir(tag);
    let mut rng = Prng::seed_from_u64(0xC0FFEE);
    let tokens: Vec<i32> =
        (0..n_seqs * cfg.seq_len).map(|_| rng.range(0, cfg.vocab) as i32).collect();
    pack_corpus(&dir, cfg.seq_len, cfg.vocab, 20, &tokens).unwrap();
    dir
}

fn engine(workers: usize, grad_accum: usize, plan: Option<BatchPlan>) -> Engine {
    let m = RefLm::new(RefLmCfg::default());
    let layout = m.layout().clone();
    let sources = Sources::Threaded(
        (0..workers).map(|_| Box::new(m.clone()) as Box<dyn GradSource + Send>).collect(),
    );
    // A ρ-schedule that actually moves across the epochs these runs
    // span, so warmup boundaries and mask re-selections interleave.
    let mask_builder = MaskBuilder::with_schedule(
        layout,
        RhoSchedule::Linear { start: 1.0, end: 0.25, epochs: 3 },
        SubspacePolicy::Blockwise(BlockPolicy::Random),
        SEED,
    );
    let cfg = EngineCfg {
        parallel: ParallelCfg { workers, grad_accum, ..Default::default() },
        schedule: LrSchedule::ConstantWarmup { warmup: 2 },
        peak_lr: 1e-3,
        lr_free_mult: 1.0,
        update_freq: UPDATE_FREQ,
        adam: AdamCfg::default(),
        clip: None,
    };
    let mut b = Engine::builder()
        .mask_builder(mask_builder)
        .cfg(cfg)
        .sources(sources)
        .init_flat(m.init_flat(SEED))
        .seqs_per_micro(RefLmCfg::default().batch as u64);
    if let Some(plan) = plan {
        b = b.batch_plan(plan);
    }
    b.build().unwrap()
}

/// `linear:1:4:768` at 64 tokens/micro and T=4: rounds run grad_accum
/// 1, 2, 4, 4, ... — the warmup spans two round boundaries (and two
/// ρ-epoch re-selections of the schedule above).
fn warmup_plan() -> BatchPlan {
    BatchPlan::new(
        BatchSchedule::Linear { start: 1, end: 4, warmup_tokens: 768 },
        TOKENS_PER_MICRO,
        UPDATE_FREQ,
    )
}

fn run<F>(engine: &mut Engine, steps: u64, batch_fn: &F) -> Vec<u32>
where
    F: Fn(u64, &mut Vec<i32>) + Sync,
{
    (0..steps).map(|_| engine.step(batch_fn).unwrap().to_bits()).collect()
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// `frugal data pack`'s library path round-trips: the written index is
/// what `DataIndex::read` returns, every shard re-verifies against its
/// pinned CRC, and an opened corpus reports the packed geometry.
#[test]
fn pack_read_verify_roundtrip() {
    let dir = packed_dir("roundtrip", 48);
    let index = DataIndex::read(&dir).unwrap();
    assert_eq!(index.seq_len, 16);
    assert_eq!(index.vocab, 64);
    assert_eq!(index.total_seqs(), 48);
    assert_eq!(index.shards.len(), 3, "48 seqs at 20/shard");
    for s in &index.shards {
        let (h, payload) = read_shard_verified(&dir.join(&s.file), s.crc32).unwrap();
        assert_eq!(u64::from(h.n_seqs), s.seqs);
        assert_eq!(payload.len() as u64, s.seqs * 16);
    }
    let sc = StreamingCorpus::open(&dir, 4, SEED).unwrap();
    assert_eq!((sc.seq_len(), sc.batch(), sc.vocab(), sc.total_seqs()), (16, 4, 64, 48));
    std::fs::remove_dir_all(&dir).ok();
}

/// Workers 1 vs 2 on a streamed shard corpus: identical loss-trace bits
/// and parameters, with the prefetcher in front on one side — the
/// prefetch ring is a cache, never a reordering.
#[test]
fn streaming_workers_1_and_2_bitwise_with_and_without_prefetch() {
    let dir = packed_dir("bitwise", 48);
    let direct = Arc::new(StreamingCorpus::open(&dir, 4, SEED).unwrap()) as Arc<dyn Corpus>;
    let behind = Arc::new(StreamingCorpus::open(&dir, 4, SEED).unwrap()) as Arc<dyn Corpus>;
    let pf = Prefetcher::new(Arc::clone(&behind), 4, 0);

    let direct_fn = |micro: u64, buf: &mut Vec<i32>| direct.fill_train_batch(micro, buf);
    let prefetch_fn = |micro: u64, buf: &mut Vec<i32>| pf.fill(micro, buf);

    let mut e1 = engine(1, 4, None);
    let mut e2 = engine(2, 4, None);
    let t1 = run(&mut e1, 10, &direct_fn);
    let t2 = run(&mut e2, 10, &prefetch_fn);
    assert_eq!(t1, t2, "loss traces diverged across workers / prefetch");
    assert_eq!(bits(&e1.flat), bits(&e2.flat), "parameters diverged");
    assert_eq!(
        e1.telemetry().get(Counter::TokensConsumed),
        e2.telemetry().get(Counter::TokensConsumed),
        "token accounting must be worker-count independent"
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// The warmup plan is consulted at round boundaries: 12 steps at T=4
/// run rounds with grad_accum 1, 2, 4 — pinned through the
/// deterministic token/sequence counters.
#[test]
fn warmup_token_accounting_follows_the_plan() {
    let plan = warmup_plan();
    assert_eq!(
        (1..=3).map(|r| plan.accum_for_round(r)).collect::<Vec<_>>(),
        vec![1, 2, 4],
        "the test geometry must cross the warmup mid-schedule"
    );
    let corpus = SyntheticStream::new(SyntheticCorpus::new(CorpusConfig::default_for_vocab(64)), 4, 16);
    let batch_fn = |micro: u64, buf: &mut Vec<i32>| corpus.fill_train_batch(micro, buf);
    let mut e = engine(1, 4, Some(plan));
    run(&mut e, 12, &batch_fn);
    // 4 steps × (1 + 2 + 4) micros × 64 tokens.
    assert_eq!(e.telemetry().get(Counter::TokensConsumed), 4 * 7 * TOKENS_PER_MICRO);
    assert_eq!(e.telemetry().get(Counter::SequencesAssigned), 4 * 7 * 4);
}

/// Kill/resume mid-warmup at workers 1/2/4 reproduces the continuous
/// workers=1 run bitwise — the active batch is recomputed from the
/// restored round, never carried as mutable state.
#[test]
fn resume_mid_warmup_is_bitwise_at_any_worker_count() {
    let dir = packed_dir("resume", 64);
    let corpus = Arc::new(StreamingCorpus::open(&dir, 4, SEED).unwrap()) as Arc<dyn Corpus>;
    let batch_fn = |micro: u64, buf: &mut Vec<i32>| corpus.fill_train_batch(micro, buf);

    let mut continuous = engine(1, 4, Some(warmup_plan()));
    let want_trace = run(&mut continuous, 12, &batch_fn);
    let want_flat = bits(&continuous.flat);

    // Save at step 8 — the round-2→3 barrier, still inside the warmup
    // (round 3 is the first at the peak batch).
    let ck = tmpdir("resume_ck");
    for resume_workers in [1usize, 2, 4] {
        let mut first = engine(1, 4, Some(warmup_plan()));
        let mut trace = run(&mut first, 8, &batch_fn);
        ckpt::save(&ck, &first.capture_state().unwrap(), SaveOptions::new(MomentCodec::Raw, 64))
            .unwrap();
        drop(first); // the "kill"
        let mut resumed = engine(resume_workers, 4, Some(warmup_plan()));
        resumed.restore_state(ckpt::load(&ck).unwrap()).unwrap();
        trace.extend(run(&mut resumed, 4, &batch_fn));
        assert_eq!(trace, want_trace, "trace diverged at resume workers={resume_workers}");
        assert_eq!(
            bits(&resumed.flat),
            want_flat,
            "parameters diverged at resume workers={resume_workers}"
        );
        std::fs::remove_dir_all(&ck).ok();
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// The schedule string in the manifest is a fingerprint: resuming under
/// a different schedule — or dropping/adding one — is rejected.
#[test]
fn restore_rejects_batch_schedule_mismatch() {
    let corpus = SyntheticStream::new(SyntheticCorpus::new(CorpusConfig::default_for_vocab(64)), 4, 16);
    let batch_fn = |micro: u64, buf: &mut Vec<i32>| corpus.fill_train_batch(micro, buf);
    let mut scheduled = engine(1, 4, Some(warmup_plan()));
    run(&mut scheduled, 4, &batch_fn);
    let st_sched = scheduled.capture_state().unwrap();

    let mut plain = engine(1, 4, None);
    run(&mut plain, 4, &batch_fn);
    let st_plain = plain.capture_state().unwrap();

    // Scheduled snapshot into a schedule-less engine (and vice versa).
    let err = engine(1, 4, None).restore_state(st_sched.clone()).unwrap_err();
    assert!(err.to_string().contains("batch schedule"), "got: {err:#}");
    let err = engine(1, 4, Some(warmup_plan())).restore_state(st_plain).unwrap_err();
    assert!(err.to_string().contains("batch schedule"), "got: {err:#}");
    // A *different* warmup is just as wrong as a missing one.
    let other = BatchPlan::new(
        BatchSchedule::Linear { start: 2, end: 4, warmup_tokens: 768 },
        TOKENS_PER_MICRO,
        UPDATE_FREQ,
    );
    let err = engine(1, 4, Some(other)).restore_state(st_sched).unwrap_err();
    assert!(err.to_string().contains("batch schedule"), "got: {err:#}");
}

/// The fingerprint survives the on-disk manifest: save → load carries
/// the canonical schedule string byte-for-byte.
#[test]
fn manifest_carries_the_schedule_fingerprint() {
    let corpus = SyntheticStream::new(SyntheticCorpus::new(CorpusConfig::default_for_vocab(64)), 4, 16);
    let batch_fn = |micro: u64, buf: &mut Vec<i32>| corpus.fill_train_batch(micro, buf);
    let mut e = engine(1, 4, Some(warmup_plan()));
    run(&mut e, 4, &batch_fn);
    let dir = tmpdir("manifest");
    ckpt::save(&dir, &e.capture_state().unwrap(), SaveOptions::new(MomentCodec::Raw, 64)).unwrap();
    let man = ckpt::CkptManifest::read(&dir).unwrap();
    assert_eq!(man.batch_schedule, "linear:1:4:768");
    assert_eq!(ckpt::load(&dir).unwrap().batch_schedule, "linear:1:4:768");
    std::fs::remove_dir_all(&dir).ok();
}

/// Data server smoke: a uds server over a packed directory returns
/// batches bit-identical to a local open, train and val.
#[test]
fn dataserve_uds_matches_local_open() {
    let dir = packed_dir("serve", 48);
    let local = StreamingCorpus::open(&dir, 4, SEED).unwrap();
    let served = Arc::new(StreamingCorpus::open(&dir, 4, SEED).unwrap()) as Arc<dyn Corpus>;
    let addr = default_addr(TransportKind::Uds);
    let server = DataServer::start(TransportKind::Uds, &addr, served).unwrap();
    let remote = RemoteCorpus::connect(
        TransportKind::Uds,
        server.addr(),
        4,
        16,
        std::time::Duration::from_secs(5),
    )
    .unwrap();
    let (mut a, mut b) = (Vec::new(), Vec::new());
    for micro in [0u64, 3, 17, 2] {
        local.fill_train_batch(micro, &mut a);
        remote.fill_train_batch(micro, &mut b);
        assert_eq!(a, b, "train micro {micro} diverged over the wire");
    }
    for idx in [0u64, 5] {
        assert_eq!(local.val_batch(idx), remote.val_batch(idx), "val {idx} diverged");
    }
    drop(server);
    std::fs::remove_dir_all(&dir).ok();
}

/// The synthetic corpus behind the `Corpus` trait is bit-identical to
/// its inherent fill path — the trait migration changed no bits.
#[test]
fn synthetic_trait_path_is_bit_identical_to_inherent_fill() {
    let inherent = SyntheticCorpus::new(CorpusConfig::default_for_vocab(64));
    let stream = SyntheticStream::new(SyntheticCorpus::new(CorpusConfig::default_for_vocab(64)), 4, 16);
    let (mut a, mut b) = (Vec::new(), Vec::new());
    for micro in [0u64, 1, 7, 100, 12345] {
        inherent.fill_train_batch(4, 16, micro, &mut a);
        stream.fill_train_batch(micro, &mut b);
        assert_eq!(a, b, "micro {micro}");
    }
    for idx in [0u64, 9] {
        assert_eq!(inherent.val_batch(4, 16, idx).tokens, stream.val_batch(idx), "val {idx}");
    }
}
