//! Integration tests for the data-parallel engine.
//!
//! The headline invariant: at a fixed global batch (`grad_accum`) and
//! seed, training with `workers = N` is **bit-identical** to
//! `workers = 1` — same per-step loss trace, same parameter vector —
//! for any thread interleaving and under injected straggler delay.
//! Plus the sharding criterion: each worker holds Adam moments for
//! `ceil(statefull_lanes / N)` lanes (± shard-granularity padding).

use frugal::coordinator::subspace::{statefull_lanes, MaskBuilder, SubspacePolicy};
use frugal::coordinator::LrSchedule;
use frugal::engine::{
    CompressCfg, CompressMode, Engine, EngineCfg, GradSource, ParallelCfg, RefLm, RefLmCfg,
    ShardPlan, Sources,
};
use frugal::optim::adamw::AdamCfg;
use frugal::optim::frugal::BlockPolicy;

const SEED: u64 = 42;

fn model() -> RefLm {
    RefLm::new(RefLmCfg::default())
}

fn engine(workers: usize, parallel: ParallelCfg, threaded: bool) -> Engine {
    let m = model();
    let layout = m.layout().clone();
    let sources = if threaded {
        Sources::Threaded(
            (0..workers).map(|_| Box::new(m.clone()) as Box<dyn GradSource + Send>).collect(),
        )
    } else {
        Sources::Local((0..workers).map(|_| Box::new(m.clone()) as Box<dyn GradSource>).collect())
    };
    let mask_builder = MaskBuilder::new(
        layout,
        0.25,
        SubspacePolicy::Blockwise(BlockPolicy::Random),
        SEED,
    );
    let cfg = EngineCfg {
        parallel: ParallelCfg { workers, ..parallel },
        schedule: LrSchedule::ConstantWarmup { warmup: 2 },
        peak_lr: 1e-3,
        lr_free_mult: 1.0,
        update_freq: 4, // exercise a subspace re-selection mid-run
        adam: AdamCfg::default(),
        clip: None,
    };
    Engine::new(mask_builder, cfg, sources, m.init_flat(SEED)).unwrap()
}

/// Deterministic micro-batch stream shared by all runs (fill-style — the
/// engine's allocation-free batch contract).
fn batch_fn(micro: u64, buf: &mut Vec<i32>) {
    let cfg = RefLmCfg::default();
    let mut rng = frugal::util::Prng::seed_from_u64(0xDA7A ^ micro.wrapping_mul(0x9E37));
    buf.clear();
    buf.extend((0..cfg.batch * cfg.seq_len).map(|_| rng.range(0, cfg.vocab) as i32));
}

fn run(engine: &mut Engine, steps: u64) -> Vec<u32> {
    let mut losses = Vec::new();
    for _ in 0..steps {
        losses.push(engine.step(&batch_fn).unwrap().to_bits());
    }
    losses
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Acceptance criterion: workers=1 vs workers=4 at the same global batch
/// — identical loss trace bits and identical parameter vectors after 10
/// steps (which span two subspace re-selections at T=4).
#[test]
fn workers_1_and_4_are_bit_identical() {
    let parallel = ParallelCfg { grad_accum: 4, ..Default::default() };
    let mut e1 = engine(1, parallel.clone(), true);
    let mut e4 = engine(4, parallel, true);
    let t1 = run(&mut e1, 10);
    let t4 = run(&mut e4, 10);
    assert_eq!(t1, t4, "per-step loss traces diverged");
    assert_eq!(bits(&e1.flat), bits(&e4.flat), "parameter vectors diverged");
    // Not a no-op run: parameters actually moved.
    let moved = e1
        .flat
        .iter()
        .zip(&model().init_flat(SEED))
        .filter(|(a, b)| a != b)
        .count();
    assert!(moved > 1000, "only {moved} lanes moved");
}

/// Same invariant across 2, 3 (uneven micro-batch split), and 8 (more
/// workers than needed) — and for threaded vs logical (local) execution.
#[test]
fn all_worker_counts_and_modes_agree() {
    let parallel = ParallelCfg { grad_accum: 6, ..Default::default() };
    let mut reference = engine(1, parallel.clone(), false);
    let want_trace = run(&mut reference, 6);
    let want_flat = bits(&reference.flat);
    for workers in [2usize, 3, 8] {
        for threaded in [false, true] {
            let mut e = engine(workers, parallel.clone(), threaded);
            let trace = run(&mut e, 6);
            assert_eq!(trace, want_trace, "workers={workers} threaded={threaded}");
            assert_eq!(bits(&e.flat), want_flat, "workers={workers} threaded={threaded}");
        }
    }
}

/// Straggler delay skews completion order but must not change a single
/// bit — the all-reduce is keyed by micro-batch index, not arrival.
#[test]
fn straggler_injection_does_not_change_bits() {
    let fast = ParallelCfg { grad_accum: 4, ..Default::default() };
    let slow = ParallelCfg { grad_accum: 4, straggler_ms: 5, timeout_ms: 1, ..Default::default() };
    let mut e_fast = engine(3, fast, true);
    let mut e_slow = engine(3, slow, true);
    let t_fast = run(&mut e_fast, 4);
    let t_slow = run(&mut e_slow, 4);
    assert_eq!(t_fast, t_slow);
    assert_eq!(bits(&e_fast.flat), bits(&e_slow.flat));
}

/// Straggler detection counts timeout events in the round report when a
/// worker is much slower than the receive timeout.
#[test]
fn straggler_timeouts_are_reported() {
    let parallel =
        ParallelCfg { grad_accum: 4, straggler_ms: 60, timeout_ms: 5, ..Default::default() };
    let mut e = engine(2, parallel, true);
    for _ in 0..2 {
        e.step(&batch_fn).unwrap();
    }
    let timeouts: u64 = e.reports().iter().map(|r| r.straggler_timeouts).sum();
    assert!(timeouts > 0, "expected timeout events with a 60ms straggler and 5ms timeout");
}

/// Sharding criterion: per-worker moment storage is 2 × ceil(K/N) floats
/// (± granularity padding), and the shards cover exactly the state-full
/// lane set of the current mask.
#[test]
fn per_worker_state_is_ceil_k_over_n() {
    for workers in [1usize, 2, 3, 4] {
        let parallel =
            ParallelCfg { grad_accum: 2, shard_granularity: 64, ..Default::default() };
        let mut e = engine(workers, parallel, true);
        e.step(&batch_fn).unwrap();
        let k = statefull_lanes(e.mask(), model().layout().flat_size).len();
        assert_eq!(e.plan().total_lanes(), k, "plan must cover the state-full set");
        let ceil = (k + workers - 1) / workers;
        let padded = (ceil + 63) / 64 * 64;
        let per_worker = e.state_floats_per_worker();
        assert_eq!(per_worker.len(), workers);
        for (w, &floats) in per_worker.iter().enumerate() {
            assert!(
                floats <= 2 * padded,
                "worker {w}: {floats} floats > 2*{padded} (K={k}, N={workers})"
            );
        }
        assert_eq!(per_worker.iter().sum::<usize>(), 2 * k, "total must be exactly 2K");
        assert_eq!(e.state_floats(), 2 * k);
    }
}

/// Subspace re-selection releases and re-partitions shard state: after a
/// round boundary the shard plan tracks the new mask.
#[test]
fn reselection_rebuilds_shards() {
    let parallel = ParallelCfg { grad_accum: 2, ..Default::default() };
    let mut e = engine(2, parallel, true);
    e.step(&batch_fn).unwrap();
    let mask1 = e.mask().to_vec();
    let k1 = e.plan().total_lanes();
    assert!(k1 > 0);
    // T=4: 20 more steps cross five re-selections.
    let mut mask_changed = false;
    for _ in 0..20 {
        e.step(&batch_fn).unwrap();
        if e.mask() != &mask1[..] {
            mask_changed = true;
        }
    }
    assert_eq!(e.round(), 6);
    assert_eq!(e.reports().len(), 6);
    assert!(mask_changed, "random blockwise mask never changed across 6 rounds");
    let flat_size = model().layout().flat_size;
    let mask_now = e.mask().to_vec();
    assert_eq!(e.plan().total_lanes(), statefull_lanes(&mask_now, flat_size).len());
}

/// The shard partitioner in isolation (unit-level, mirrors engine use).
#[test]
fn shard_plan_partitions_exactly() {
    let lanes: Vec<u32> = (0..1000u32).filter(|l| l % 7 != 0).collect();
    let k = lanes.len();
    for workers in [1usize, 2, 3, 5, 8] {
        let plan = ShardPlan::partition(lanes.clone(), workers, 1);
        let ceil = (k + workers - 1) / workers;
        assert_eq!(plan.max_shard_len(), ceil);
        let mut recovered: Vec<u32> = Vec::new();
        for w in 0..workers {
            recovered.extend_from_slice(plan.lanes_of(w));
        }
        assert_eq!(recovered, lanes);
    }
}

/// Gradient-accumulation sanity: more micro-batches per step changes the
/// data (it IS a bigger global batch) but stays deterministic run-to-run.
#[test]
fn engine_runs_are_reproducible() {
    let parallel = ParallelCfg { grad_accum: 3, ..Default::default() };
    let mut a = engine(2, parallel.clone(), true);
    let mut b = engine(2, parallel, true);
    assert_eq!(run(&mut a, 5), run(&mut b, 5));
    assert_eq!(bits(&a.flat), bits(&b.flat));
}

/// `[parallel]` config with compression mode `mode` (small scale blocks
/// so several blocks exist at test sizes).
fn compressed(mode: CompressMode) -> ParallelCfg {
    ParallelCfg {
        grad_accum: 4,
        compress: CompressCfg { mode, block: 64 },
        ..Default::default()
    }
}

/// The headline invariant survives compression: within any fixed codec,
/// the per-step loss trace and final parameters are bit-identical across
/// worker counts and execution modes. 10 steps at T=4 cross two subspace
/// re-selections, so codec plans and EF residuals rebuild mid-run.
#[test]
fn compressed_workers_are_bit_identical() {
    for mode in [CompressMode::SignEf, CompressMode::Q8, CompressMode::Split] {
        let mut e1 = engine(1, compressed(mode), true);
        let t1 = run(&mut e1, 10);
        for workers in [2usize, 4] {
            for threaded in [false, true] {
                let mut e = engine(workers, compressed(mode), threaded);
                let t = run(&mut e, 10);
                assert_eq!(t, t1, "{mode:?} workers={workers} threaded={threaded}");
                assert_eq!(
                    bits(&e.flat),
                    bits(&e1.flat),
                    "{mode:?} workers={workers} threaded={threaded}"
                );
            }
        }
    }
}

/// Compression under straggler skew: arrival order changes, bits don't.
#[test]
fn compressed_straggler_injection_does_not_change_bits() {
    let fast = compressed(CompressMode::Split);
    let slow = ParallelCfg { straggler_ms: 5, timeout_ms: 1, ..compressed(CompressMode::Split) };
    let mut e_fast = engine(3, fast, true);
    let mut e_slow = engine(3, slow, true);
    assert_eq!(run(&mut e_fast, 4), run(&mut e_slow, 4));
    assert_eq!(bits(&e_fast.flat), bits(&e_slow.flat));
}

/// Convergence parity (the acceptance bound): the split codec — 1-bit
/// EF-sign on the state-free lanes, q8 on the state-full lanes — tracks
/// the uncompressed run within 2% on the reference LM.
#[test]
fn split_codec_tracks_uncompressed_loss() {
    let steps = 24;
    let mut plain = engine(2, ParallelCfg { grad_accum: 4, ..Default::default() }, true);
    let mut comp = engine(2, compressed(CompressMode::Split), true);
    let mut lu = Vec::new();
    let mut lc = Vec::new();
    for _ in 0..steps {
        lu.push(plain.step(&batch_fn).unwrap());
        lc.push(comp.step(&batch_fn).unwrap());
    }
    let tail = |v: &[f32]| v[v.len() - 4..].iter().map(|&x| x as f64).sum::<f64>() / 4.0;
    let (tu, tc) = (tail(&lu), tail(&lc));
    let gap = (tc - tu).abs() / tu;
    assert!(
        gap <= 0.02,
        "split-codec loss gap {:.3}% exceeds 2% (uncompressed {tu:.4}, split {tc:.4})",
        100.0 * gap
    );
    assert!(lu.iter().chain(lc.iter()).all(|l| l.is_finite()));
}

/// The `[parallel] pipeline` knob changes only the collector's schedule
/// (overlapped vs barrier) — never the math: at workers 1/2/4 ×
/// compress none/split, traces and final parameters are bitwise equal
/// with pipelining on and off (the tree grouping is index-keyed either
/// way). 8 steps at T=4 cross a subspace re-selection mid-run.
#[test]
fn pipeline_toggle_is_bit_identical() {
    for mode in [CompressMode::None, CompressMode::Split] {
        let mk = |pipeline: bool, workers: usize| {
            engine(
                workers,
                ParallelCfg {
                    grad_accum: 4,
                    pipeline,
                    compress: CompressCfg { mode, block: 64 },
                    ..Default::default()
                },
                true,
            )
        };
        let mut reference = mk(true, 1);
        let want = run(&mut reference, 8);
        let want_flat = bits(&reference.flat);
        for workers in [1usize, 2, 4] {
            for pipeline in [true, false] {
                let mut e = mk(pipeline, workers);
                assert_eq!(
                    run(&mut e, 8),
                    want,
                    "{mode:?} workers={workers} pipeline={pipeline}"
                );
                assert_eq!(
                    bits(&e.flat),
                    want_flat,
                    "{mode:?} workers={workers} pipeline={pipeline}"
                );
            }
        }
    }
}

/// The reduce-tree buffer pool reaches steady state: after the first
/// step of a round every message grab is served from recycled storage
/// (misses stop growing), across compression modes.
#[test]
fn buffer_pool_reaches_steady_state() {
    for mode in [CompressMode::None, CompressMode::Split] {
        let mut e = engine(2, compressed(mode), true);
        e.step(&batch_fn).unwrap();
        e.step(&batch_fn).unwrap(); // first steady-state step of round 1
        let after_warm = e.pool_stats();
        for _ in 0..3 {
            e.step(&batch_fn).unwrap();
        }
        let now = e.pool_stats();
        assert!(now.grabs > after_warm.grabs, "{mode:?}: pool unused");
        assert_eq!(
            now.misses, after_warm.misses,
            "{mode:?}: steady-state steps still allocate fresh messages"
        );
    }
}

/// Wire accounting: the split codec ships ≥ 3× fewer reduce-tree bytes
/// than fp32, the uncompressed engine meters but does not reduce, and EF
/// residual state exists only when a sign group is active.
#[test]
fn split_codec_cuts_wire_bytes_3x() {
    let mut dense = engine(2, ParallelCfg { grad_accum: 4, ..Default::default() }, true);
    let mut split = engine(2, compressed(CompressMode::Split), true);
    for _ in 0..2 {
        dense.step(&batch_fn).unwrap();
        split.step(&batch_fn).unwrap();
    }
    assert_eq!(dense.wire_bytes_total(), dense.wire_dense_bytes_total());
    assert_eq!(dense.residual_floats(), 0);
    assert_eq!(split.wire_dense_bytes_total(), dense.wire_dense_bytes_total());
    assert!(
        dense.wire_bytes_total() >= 3 * split.wire_bytes_total(),
        "split wire bytes {} not 3x under dense {}",
        split.wire_bytes_total(),
        dense.wire_bytes_total()
    );
    // EF residuals: one buffer per micro-batch slot, state-free lanes
    // each, released and re-sized with the round's lane sets.
    let free_lanes = split.compress_plan().residual_len();
    assert!(free_lanes > 0);
    assert_eq!(split.residual_floats(), 4 * free_lanes);
    // Round reports carry the same accounting.
    let report = split.reports().last().unwrap();
    assert!(report.wire_reduction() >= 3.0);
}
