//! Integration tests for the data-parallel engine.
//!
//! The headline invariant: at a fixed global batch (`grad_accum`) and
//! seed, training with `workers = N` is **bit-identical** to
//! `workers = 1` — same per-step loss trace, same parameter vector —
//! for any thread interleaving and under injected straggler delay.
//! Plus the sharding criterion: each worker holds Adam moments for
//! `ceil(statefull_lanes / N)` lanes (± shard-granularity padding).

use frugal::coordinator::subspace::{statefull_lanes, MaskBuilder, SubspacePolicy};
use frugal::coordinator::LrSchedule;
use frugal::engine::{
    CompressCfg, CompressMode, Engine, EngineCfg, GradSource, ParallelCfg, RefLm, RefLmCfg,
    ShardPlan, Sources,
};
use frugal::optim::adamw::AdamCfg;
use frugal::optim::frugal::BlockPolicy;

const SEED: u64 = 42;

fn model() -> RefLm {
    RefLm::new(RefLmCfg::default())
}

fn engine(workers: usize, parallel: ParallelCfg, threaded: bool) -> Engine {
    let m = model();
    let layout = m.layout().clone();
    let sources = if threaded {
        Sources::Threaded(
            (0..workers).map(|_| Box::new(m.clone()) as Box<dyn GradSource + Send>).collect(),
        )
    } else {
        Sources::Local((0..workers).map(|_| Box::new(m.clone()) as Box<dyn GradSource>).collect())
    };
    let mask_builder = MaskBuilder::new(
        layout,
        0.25,
        SubspacePolicy::Blockwise(BlockPolicy::Random),
        SEED,
    );
    let cfg = EngineCfg {
        parallel: ParallelCfg { workers, ..parallel },
        schedule: LrSchedule::ConstantWarmup { warmup: 2 },
        peak_lr: 1e-3,
        lr_free_mult: 1.0,
        update_freq: 4, // exercise a subspace re-selection mid-run
        adam: AdamCfg::default(),
        clip: None,
    };
    Engine::builder()
        .mask_builder(mask_builder)
        .cfg(cfg)
        .sources(sources)
        .init_flat(m.init_flat(SEED))
        .build()
        .unwrap()
}

/// Deterministic micro-batch stream shared by all runs (fill-style — the
/// engine's allocation-free batch contract).
fn batch_fn(micro: u64, buf: &mut Vec<i32>) {
    let cfg = RefLmCfg::default();
    let mut rng = frugal::util::Prng::seed_from_u64(0xDA7A ^ micro.wrapping_mul(0x9E37));
    buf.clear();
    buf.extend((0..cfg.batch * cfg.seq_len).map(|_| rng.range(0, cfg.vocab) as i32));
}

fn run(engine: &mut Engine, steps: u64) -> Vec<u32> {
    let mut losses = Vec::new();
    for _ in 0..steps {
        losses.push(engine.step(&batch_fn).unwrap().to_bits());
    }
    losses
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Acceptance criterion: workers=1 vs workers=4 at the same global batch
/// — identical loss trace bits and identical parameter vectors after 10
/// steps (which span two subspace re-selections at T=4).
#[test]
fn workers_1_and_4_are_bit_identical() {
    let parallel = ParallelCfg { grad_accum: 4, ..Default::default() };
    let mut e1 = engine(1, parallel.clone(), true);
    let mut e4 = engine(4, parallel, true);
    let t1 = run(&mut e1, 10);
    let t4 = run(&mut e4, 10);
    assert_eq!(t1, t4, "per-step loss traces diverged");
    assert_eq!(bits(&e1.flat), bits(&e4.flat), "parameter vectors diverged");
    // Not a no-op run: parameters actually moved.
    let moved = e1
        .flat
        .iter()
        .zip(&model().init_flat(SEED))
        .filter(|(a, b)| a != b)
        .count();
    assert!(moved > 1000, "only {moved} lanes moved");
}

/// Same invariant across 2, 3 (uneven micro-batch split), and 8 (more
/// workers than needed) — and for threaded vs logical (local) execution.
#[test]
fn all_worker_counts_and_modes_agree() {
    let parallel = ParallelCfg { grad_accum: 6, ..Default::default() };
    let mut reference = engine(1, parallel.clone(), false);
    let want_trace = run(&mut reference, 6);
    let want_flat = bits(&reference.flat);
    for workers in [2usize, 3, 8] {
        for threaded in [false, true] {
            let mut e = engine(workers, parallel.clone(), threaded);
            let trace = run(&mut e, 6);
            assert_eq!(trace, want_trace, "workers={workers} threaded={threaded}");
            assert_eq!(bits(&e.flat), want_flat, "workers={workers} threaded={threaded}");
        }
    }
}

/// Straggler delay skews completion order but must not change a single
/// bit — the all-reduce is keyed by micro-batch index, not arrival.
#[test]
fn straggler_injection_does_not_change_bits() {
    let fast = ParallelCfg { grad_accum: 4, ..Default::default() };
    let slow = ParallelCfg { grad_accum: 4, straggler_ms: 5, timeout_ms: 1, ..Default::default() };
    let mut e_fast = engine(3, fast, true);
    let mut e_slow = engine(3, slow, true);
    let t_fast = run(&mut e_fast, 4);
    let t_slow = run(&mut e_slow, 4);
    assert_eq!(t_fast, t_slow);
    assert_eq!(bits(&e_fast.flat), bits(&e_slow.flat));
}

/// Straggler detection counts timeout events in the round report when a
/// worker is much slower than the receive timeout.
#[test]
fn straggler_timeouts_are_reported() {
    let parallel =
        ParallelCfg { grad_accum: 4, straggler_ms: 60, timeout_ms: 5, ..Default::default() };
    let mut e = engine(2, parallel, true);
    for _ in 0..2 {
        e.step(&batch_fn).unwrap();
    }
    let timeouts: u64 = e.reports().iter().map(|r| r.straggler_timeouts).sum();
    assert!(timeouts > 0, "expected timeout events with a 60ms straggler and 5ms timeout");
}

/// Sharding criterion: per-worker moment storage is 2 × ceil(K/N) floats
/// (± granularity padding), and the shards cover exactly the state-full
/// lane set of the current mask.
#[test]
fn per_worker_state_is_ceil_k_over_n() {
    for workers in [1usize, 2, 3, 4] {
        let parallel =
            ParallelCfg { grad_accum: 2, shard_granularity: 64, ..Default::default() };
        let mut e = engine(workers, parallel, true);
        e.step(&batch_fn).unwrap();
        let k = statefull_lanes(e.mask(), model().layout().flat_size).len();
        assert_eq!(e.plan().total_lanes(), k, "plan must cover the state-full set");
        let ceil = (k + workers - 1) / workers;
        let padded = (ceil + 63) / 64 * 64;
        let per_worker = e.state_floats_per_worker();
        assert_eq!(per_worker.len(), workers);
        for (w, &floats) in per_worker.iter().enumerate() {
            assert!(
                floats <= 2 * padded,
                "worker {w}: {floats} floats > 2*{padded} (K={k}, N={workers})"
            );
        }
        assert_eq!(per_worker.iter().sum::<usize>(), 2 * k, "total must be exactly 2K");
        assert_eq!(e.state_floats(), 2 * k);
    }
}

/// Subspace re-selection releases and re-partitions shard state: after a
/// round boundary the shard plan tracks the new mask.
#[test]
fn reselection_rebuilds_shards() {
    let parallel = ParallelCfg { grad_accum: 2, ..Default::default() };
    let mut e = engine(2, parallel, true);
    e.step(&batch_fn).unwrap();
    let mask1 = e.mask().to_vec();
    let k1 = e.plan().total_lanes();
    assert!(k1 > 0);
    // T=4: 20 more steps cross five re-selections.
    let mut mask_changed = false;
    for _ in 0..20 {
        e.step(&batch_fn).unwrap();
        if e.mask() != &mask1[..] {
            mask_changed = true;
        }
    }
    assert_eq!(e.round(), 6);
    assert_eq!(e.reports().len(), 6);
    assert!(mask_changed, "random blockwise mask never changed across 6 rounds");
    let flat_size = model().layout().flat_size;
    let mask_now = e.mask().to_vec();
    assert_eq!(e.plan().total_lanes(), statefull_lanes(&mask_now, flat_size).len());
}

/// The shard partitioner in isolation (unit-level, mirrors engine use).
#[test]
fn shard_plan_partitions_exactly() {
    let lanes: Vec<u32> = (0..1000u32).filter(|l| l % 7 != 0).collect();
    let k = lanes.len();
    for workers in [1usize, 2, 3, 5, 8] {
        let plan = ShardPlan::partition(lanes.clone(), workers, 1);
        let ceil = (k + workers - 1) / workers;
        assert_eq!(plan.max_shard_len(), ceil);
        let mut recovered: Vec<u32> = Vec::new();
        for w in 0..workers {
            recovered.extend_from_slice(plan.lanes_of(w));
        }
        assert_eq!(recovered, lanes);
    }
}

/// Gradient-accumulation sanity: more micro-batches per step changes the
/// data (it IS a bigger global batch) but stays deterministic run-to-run.
#[test]
fn engine_runs_are_reproducible() {
    let parallel = ParallelCfg { grad_accum: 3, ..Default::default() };
    let mut a = engine(2, parallel.clone(), true);
    let mut b = engine(2, parallel, true);
    assert_eq!(run(&mut a, 5), run(&mut b, 5));
    assert_eq!(bits(&a.flat), bits(&b.flat));
}

/// `[parallel]` config with compression mode `mode` (small scale blocks
/// so several blocks exist at test sizes).
fn compressed(mode: CompressMode) -> ParallelCfg {
    ParallelCfg {
        grad_accum: 4,
        compress: CompressCfg { mode, block: 64 },
        ..Default::default()
    }
}

/// The headline invariant survives compression: within any fixed codec,
/// the per-step loss trace and final parameters are bit-identical across
/// worker counts and execution modes. 10 steps at T=4 cross two subspace
/// re-selections, so codec plans and EF residuals rebuild mid-run.
#[test]
fn compressed_workers_are_bit_identical() {
    for mode in [
        CompressMode::SignEf,
        CompressMode::Q8,
        CompressMode::Split,
        CompressMode::TopK { k_permille: 10 },
        CompressMode::Q4,
        CompressMode::Adaptive { budget_permille: 20 },
    ] {
        let mut e1 = engine(1, compressed(mode), true);
        let t1 = run(&mut e1, 10);
        for workers in [2usize, 4] {
            for threaded in [false, true] {
                let mut e = engine(workers, compressed(mode), threaded);
                let t = run(&mut e, 10);
                assert_eq!(t, t1, "{mode:?} workers={workers} threaded={threaded}");
                assert_eq!(
                    bits(&e.flat),
                    bits(&e1.flat),
                    "{mode:?} workers={workers} threaded={threaded}"
                );
            }
        }
    }
}

/// Compression under straggler skew: arrival order changes, bits don't.
#[test]
fn compressed_straggler_injection_does_not_change_bits() {
    let fast = compressed(CompressMode::Split);
    let slow = ParallelCfg { straggler_ms: 5, timeout_ms: 1, ..compressed(CompressMode::Split) };
    let mut e_fast = engine(3, fast, true);
    let mut e_slow = engine(3, slow, true);
    assert_eq!(run(&mut e_fast, 4), run(&mut e_slow, 4));
    assert_eq!(bits(&e_fast.flat), bits(&e_slow.flat));
}

/// Convergence parity (the acceptance bound): the split codec — 1-bit
/// EF-sign on the state-free lanes, q8 on the state-full lanes — tracks
/// the uncompressed run within 2% on the reference LM.
#[test]
fn split_codec_tracks_uncompressed_loss() {
    let steps = 24;
    let mut plain = engine(2, ParallelCfg { grad_accum: 4, ..Default::default() }, true);
    let mut comp = engine(2, compressed(CompressMode::Split), true);
    let mut lu = Vec::new();
    let mut lc = Vec::new();
    for _ in 0..steps {
        lu.push(plain.step(&batch_fn).unwrap());
        lc.push(comp.step(&batch_fn).unwrap());
    }
    let tail = |v: &[f32]| v[v.len() - 4..].iter().map(|&x| x as f64).sum::<f64>() / 4.0;
    let (tu, tc) = (tail(&lu), tail(&lc));
    let gap = (tc - tu).abs() / tu;
    assert!(
        gap <= 0.02,
        "split-codec loss gap {:.3}% exceeds 2% (uncompressed {tu:.4}, split {tc:.4})",
        100.0 * gap
    );
    assert!(lu.iter().chain(lc.iter()).all(|l| l.is_finite()));
}

/// The `[parallel] pipeline` knob changes only the collector's schedule
/// (overlapped vs barrier) — never the math: at workers 1/2/4 ×
/// compress none/split, traces and final parameters are bitwise equal
/// with pipelining on and off (the tree grouping is index-keyed either
/// way). 8 steps at T=4 cross a subspace re-selection mid-run.
#[test]
fn pipeline_toggle_is_bit_identical() {
    for mode in [CompressMode::None, CompressMode::Split] {
        let mk = |pipeline: bool, workers: usize| {
            engine(
                workers,
                ParallelCfg {
                    grad_accum: 4,
                    pipeline,
                    compress: CompressCfg { mode, block: 64 },
                    ..Default::default()
                },
                true,
            )
        };
        let mut reference = mk(true, 1);
        let want = run(&mut reference, 8);
        let want_flat = bits(&reference.flat);
        for workers in [1usize, 2, 4] {
            for pipeline in [true, false] {
                let mut e = mk(pipeline, workers);
                assert_eq!(
                    run(&mut e, 8),
                    want,
                    "{mode:?} workers={workers} pipeline={pipeline}"
                );
                assert_eq!(
                    bits(&e.flat),
                    want_flat,
                    "{mode:?} workers={workers} pipeline={pipeline}"
                );
            }
        }
    }
}

/// The reduce-tree buffer pool reaches steady state: after the first
/// step of a round every message grab is served from recycled storage
/// (misses stop growing), across compression modes.
#[test]
fn buffer_pool_reaches_steady_state() {
    for mode in [CompressMode::None, CompressMode::Split] {
        let mut e = engine(2, compressed(mode), true);
        e.step(&batch_fn).unwrap();
        e.step(&batch_fn).unwrap(); // first steady-state step of round 1
        let after_warm = e.pool_stats();
        for _ in 0..3 {
            e.step(&batch_fn).unwrap();
        }
        let now = e.pool_stats();
        assert!(now.grabs > after_warm.grabs, "{mode:?}: pool unused");
        assert_eq!(
            now.misses, after_warm.misses,
            "{mode:?}: steady-state steps still allocate fresh messages"
        );
    }
}

/// Wire accounting: the split codec ships ≥ 3× fewer reduce-tree bytes
/// than fp32, the uncompressed engine meters but does not reduce, and EF
/// residual state exists only when a sign group is active.
#[test]
fn split_codec_cuts_wire_bytes_3x() {
    let mut dense = engine(2, ParallelCfg { grad_accum: 4, ..Default::default() }, true);
    let mut split = engine(2, compressed(CompressMode::Split), true);
    for _ in 0..2 {
        dense.step(&batch_fn).unwrap();
        split.step(&batch_fn).unwrap();
    }
    let dw = dense.wire_stats();
    let sw = split.wire_stats();
    assert_eq!(dw.bytes, dw.dense_bytes);
    assert_eq!(dense.residual_floats(), 0);
    assert_eq!(sw.dense_bytes, dw.dense_bytes);
    assert!(
        dw.bytes >= 3 * sw.bytes,
        "split wire bytes {} not 3x under dense {}",
        sw.bytes,
        dw.bytes
    );
    // EF residuals: one buffer per micro-batch slot, state-free lanes
    // each, released and re-sized with the round's lane sets.
    let free_lanes = split.compress_plan().residual_len();
    assert!(free_lanes > 0);
    assert_eq!(split.residual_floats(), 4 * free_lanes);
    // Round reports carry the same accounting.
    let report = split.reports().last().unwrap();
    assert!(report.wire_reduction() >= 3.0);
}

// ---------------------------------------------------------------------------
// Adaptive density (variable-ρ): elastic state re-provisioning
// ---------------------------------------------------------------------------

/// Engine over an explicit MaskBuilder (variable-ρ schedules, demoted
/// roles, tiny-K cases) — the builder's layout must match `m`'s.
fn engine_with_builder(
    m: &RefLm,
    mb: MaskBuilder,
    workers: usize,
    parallel: ParallelCfg,
    update_freq: u64,
) -> Engine {
    let sources = Sources::Threaded(
        (0..workers).map(|_| Box::new(m.clone()) as Box<dyn GradSource + Send>).collect(),
    );
    let cfg = EngineCfg {
        parallel: ParallelCfg { workers, ..parallel },
        schedule: LrSchedule::ConstantWarmup { warmup: 2 },
        peak_lr: 1e-3,
        lr_free_mult: 1.0,
        update_freq,
        adam: AdamCfg::default(),
        clip: None,
    };
    Engine::builder()
        .mask_builder(mb)
        .cfg(cfg)
        .sources(sources)
        .init_flat(m.init_flat(SEED))
        .build()
        .unwrap()
}

/// The tentpole invariant: `workers 1 ≡ workers N`, bitwise, under a
/// *changing* ρ — a 2-step decay crossing two K changes in 16 steps at
/// T=4 — for compress none and split. Every epoch whose K shrinks
/// forces the engine to rebuild its shard/compress plans and release +
/// re-allocate the Adam moment shards; none of that may move a bit.
#[test]
fn variable_rho_schedule_is_bit_identical_across_workers() {
    let sched = frugal::schedule::RhoSchedule::parse("step:0.5:0.5:2:0.05").unwrap();
    for mode in [CompressMode::None, CompressMode::Split] {
        let parallel = ParallelCfg {
            grad_accum: 4,
            compress: CompressCfg { mode, block: 64 },
            ..Default::default()
        };
        let m = model();
        let build = |workers: usize| {
            let mb = MaskBuilder::with_schedule(
                m.layout().clone(),
                sched.clone(),
                SubspacePolicy::Blockwise(BlockPolicy::Random),
                SEED,
            );
            engine_with_builder(&m, mb, workers, parallel.clone(), 4)
        };
        let mut e1 = build(1);
        let t1 = run(&mut e1, 16);
        for workers in [2usize, 4] {
            let mut e = build(workers);
            assert_eq!(run(&mut e, 16), t1, "{mode:?} workers={workers}");
            assert_eq!(bits(&e.flat), bits(&e1.flat), "{mode:?} workers={workers}");
        }
    }
}

/// The declining footprint is real, not just analytic: under a decaying
/// schedule with the exact-width RandK policy, each epoch's sharded
/// Adam state is exactly 2·K(epoch) floats, K never grows, and it
/// strictly shrinks across the decay. Round reports record ρ(epoch).
#[test]
fn rho_decay_shrinks_sharded_state_per_epoch() {
    let sched = frugal::schedule::RhoSchedule::parse("linear:0.5:0.1:4").unwrap();
    let m = model();
    let flat_size = m.layout().flat_size;
    let mb = MaskBuilder::with_schedule(
        m.layout().clone(),
        sched.clone(),
        SubspacePolicy::RandK,
        SEED,
    );
    let parallel = ParallelCfg { grad_accum: 2, ..Default::default() };
    let mut e = engine_with_builder(&m, mb, 2, parallel, 3);
    let mut per_epoch_k = Vec::new();
    for step in 0..15 {
        e.step(&batch_fn).unwrap();
        if step % 3 == 0 {
            // First step of each round: fresh plan + fresh moments.
            let k = statefull_lanes(e.mask(), flat_size).len();
            assert_eq!(e.plan().total_lanes(), k);
            assert_eq!(e.state_floats(), 2 * k, "state must re-provision to 2*K");
            per_epoch_k.push(k);
        }
    }
    assert_eq!(per_epoch_k.len(), 5);
    for w in per_epoch_k.windows(2) {
        assert!(w[1] <= w[0], "K grew under a decaying schedule: {per_epoch_k:?}");
    }
    assert!(
        per_epoch_k[4] < per_epoch_k[0],
        "decay never shrank K: {per_epoch_k:?}"
    );
    // Reports carry the schedule: rho column matches rho_at(epoch).
    for (i, r) in e.reports().iter().enumerate() {
        let want = sched.rho_at(i as u64) as f32;
        assert!((r.rho - want).abs() < 1e-6, "round {}: rho {} vs {want}", r.round, r.rho);
    }
}

/// ρ edge cases: an all-state-free epoch (K = 0) and an all-state-full
/// epoch (K = every real lane) must produce valid empty/full shard
/// plans — no `rho: 0.0` special-casing anywhere — and both stay
/// bit-identical across worker counts, compressed or not.
#[test]
fn k_zero_and_k_full_epochs_produce_valid_plans() {
    use frugal::optim::Role;
    for mode in [CompressMode::None, CompressMode::Split] {
        let parallel = ParallelCfg {
            grad_accum: 2,
            compress: CompressCfg { mode, block: 64 },
            ..Default::default()
        };
        // K = 0: rho 0 with every role demoted to state-free.
        let m = model();
        let build_zero = |workers: usize| {
            let mut mb =
                MaskBuilder::new(m.layout().clone(), 0.0, SubspacePolicy::RandK, SEED);
            mb.statefree_roles = vec![Role::Embed, Role::Norm, Role::Output];
            engine_with_builder(&m, mb, workers, parallel.clone(), 4)
        };
        let mut z1 = build_zero(1);
        let tz = run(&mut z1, 6);
        assert_eq!(z1.plan().total_lanes(), 0, "{mode:?}: K must be 0");
        assert_eq!(z1.state_floats(), 0, "{mode:?}: no Adam state at K=0");
        assert!(tz.iter().all(|b| f32::from_bits(*b).is_finite()));
        // The pure-signSGD epoch still trains (params moved).
        assert_ne!(bits(&z1.flat), bits(&m.init_flat(SEED)), "{mode:?}");
        let mut z2 = build_zero(2);
        assert_eq!(run(&mut z2, 6), tz, "{mode:?}: K=0 not worker-invariant");

        // K = total: rho 1 — every real lane state-full, no free lanes.
        let build_full = |workers: usize| {
            let mb = MaskBuilder::new(m.layout().clone(), 1.0, SubspacePolicy::RandK, SEED);
            engine_with_builder(&m, mb, workers, parallel.clone(), 4)
        };
        let mut f1 = build_full(1);
        let tf = run(&mut f1, 6);
        assert_eq!(f1.plan().total_lanes(), m.layout().flat_size, "{mode:?}");
        assert_eq!(f1.state_floats(), 2 * m.layout().flat_size, "{mode:?}");
        // No state-free lanes → the sign/EF group is empty.
        assert_eq!(f1.residual_floats(), 0, "{mode:?}");
        let mut f2 = build_full(3);
        assert_eq!(run(&mut f2, 6), tf, "{mode:?}: K=full not worker-invariant");
        assert_eq!(bits(&f2.flat), bits(&f1.flat), "{mode:?}");
    }
}

/// Worker starvation: more workers than state-full lanes. A 1-lane
/// shard plan parks the lane on worker 0 and leaves the rest empty —
/// updates still land, empty shards are no-ops, and the engine-level
/// run (workers > K) is bit-identical to workers = 1.
#[test]
fn worker_starvation_more_workers_than_lanes() {
    // Unit level: a single lane across 4 workers.
    let plan = ShardPlan::partition(vec![7], 4, 64);
    assert_eq!(plan.total_lanes(), 1);
    assert_eq!(plan.shard_len(0), 1);
    for w in 1..4 {
        assert_eq!(plan.shard_len(w), 0, "worker {w} should be empty");
        assert!(plan.lanes_of(w).is_empty());
    }
    // Engine level: a tiny model where RandK rounds to K = 2 linear
    // lanes, run at workers = 4 > K.
    let cfg = RefLmCfg { vocab: 32, d_model: 8, d_ff: 16, n_layers: 1, seq_len: 8, batch: 2 };
    let m = RefLm::new(cfg.clone());
    let tiny_batch = move |micro: u64, buf: &mut Vec<i32>| {
        let mut rng = frugal::util::Prng::seed_from_u64(0x71AB ^ micro.wrapping_mul(0x9E37));
        buf.clear();
        buf.extend((0..cfg.batch * cfg.seq_len).map(|_| rng.range(0, cfg.vocab) as i32));
    };
    let build = |workers: usize| {
        use frugal::optim::Role;
        // Largest linears are 8x16 = 128 lanes: rho 0.008 -> k = 1 for
        // those, 0 for the 64-lane ones.
        let mut mb =
            MaskBuilder::new(m.layout().clone(), 0.008, SubspacePolicy::RandK, SEED);
        mb.statefree_roles = vec![Role::Embed, Role::Norm, Role::Output];
        let parallel = ParallelCfg { grad_accum: 2, shard_granularity: 1, ..Default::default() };
        engine_with_builder(&m, mb, workers, parallel, 4)
    };
    let mut e1 = build(1);
    let mut e4 = build(4);
    let t1: Vec<u32> = (0..6).map(|_| e1.step(&tiny_batch).unwrap().to_bits()).collect();
    let t4: Vec<u32> = (0..6).map(|_| e4.step(&tiny_batch).unwrap().to_bits()).collect();
    let k = e4.plan().total_lanes();
    assert!(k >= 1 && k < 4, "expected 1..4 state-full lanes, got {k}");
    assert!(
        e4.state_floats_per_worker().iter().filter(|&&f| f == 0).count() >= 4 - k,
        "at least {} workers should hold no state (K={k})",
        4 - k
    );
    assert_eq!(t4, t1, "starved workers changed the math");
    assert_eq!(bits(&e4.flat), bits(&e1.flat));
}
