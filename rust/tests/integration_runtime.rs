//! Integration tests over the PJRT runtime + AOT artifacts.
//!
//! These exercise the real three-layer composition: HLO artifacts built by
//! `make artifacts` are loaded and executed, and the fused train-step is
//! checked against the (grad artifact + optimizer kernel artifact)
//! composition — the Rust mirror of the python test
//! `test_train_step_matches_manual_composition`.
//!
//! Skipped (with a message) when artifacts are absent.

use std::path::Path;

use frugal::coordinator::subspace::{MaskBuilder, SubspacePolicy};
use frugal::coordinator::LrSchedule;
use frugal::data::{CorpusConfig, SyntheticCorpus};
use frugal::optim::frugal::BlockPolicy;
use frugal::runtime::{lit_f32, lit_i32_2d, lit_scalar1, to_scalar_f32, to_vec_f32, Manifest,
                      Runtime};
use frugal::train::{init_flat, FusedTrainer, GradTrainer, Session};
use frugal::TrainConfig;

/// Feature/artifact gate for every test in this file: these tests need
/// both the AOT artifacts (`make artifacts`) and a real PJRT runtime (a
/// build against the actual `xla` crate, not the offline stub). On
/// machines with neither they skip with a message instead of failing —
/// `cargo test -q` must pass on an artifact-less checkout.
fn open() -> Option<(Runtime, Manifest)> {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return None;
    }
    let rt = match Runtime::cpu() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("SKIP: PJRT runtime unavailable ({e})");
            return None;
        }
    };
    // A manifest that exists but fails to parse is a real regression in
    // the artifact builder — fail loudly rather than skip.
    let man = Manifest::load(dir)
        .expect("artifacts/manifest.json exists but failed to parse; re-run `make artifacts`");
    Some((rt, man))
}

#[test]
fn eval_artifact_gives_uniform_loss_at_init() {
    let Some((rt, man)) = open() else { return };
    let entry = man.model("test").unwrap().clone();
    let session = Session::open(&rt, &man, "test").unwrap();
    let flat = init_flat(&entry, 0);
    let corpus = SyntheticCorpus::new(CorpusConfig::default_for_vocab(entry.vocab));
    let loss = session
        .eval_loss(&flat, 2, |i| corpus.val_batch(entry.batch, entry.seq_len, i).tokens)
        .unwrap();
    let uniform = (entry.vocab as f64).ln();
    assert!((loss - uniform).abs() < 0.5, "init loss {loss} vs ln(V) {uniform}");
}

#[test]
fn grad_artifact_padding_is_zero() {
    let Some((rt, man)) = open() else { return };
    let entry = man.model("test").unwrap().clone();
    let corpus = SyntheticCorpus::new(CorpusConfig::default_for_vocab(entry.vocab));
    let cfg = TrainConfig { model: "test".into(), optimizer: "adamw".into(),
                            ..Default::default() };
    let layout = entry.layout();
    let opt = cfg.build_optimizer(&layout).unwrap();
    let tr = GradTrainer::new(&rt, &man, "test", opt, cfg.schedule.clone(), 1e-3, 0).unwrap();
    let mut tokens = Vec::new();
    corpus.fill_train_batch(entry.batch, entry.seq_len, 0, &mut tokens);
    let (_, grads) = tr.loss_and_grad(&tokens).unwrap();
    assert_eq!(grads.len(), entry.padded_size);
    for lane in entry.flat_size..entry.padded_size {
        assert_eq!(grads[lane], 0.0, "padding grad at {lane}");
    }
    let nonzero = grads[..entry.flat_size].iter().filter(|&&g| g != 0.0).count();
    assert!(nonzero as f64 > 0.9 * entry.flat_size as f64, "only {nonzero} grads non-zero");
}

/// The fused step artifact == grad artifact + frugal_update kernel
/// artifact, composed in Rust. This pins down that the three layers agree
/// end-to-end through PJRT.
#[test]
fn fused_step_matches_grad_plus_kernel_composition() {
    let Some((rt, man)) = open() else { return };
    let entry = man.model("test").unwrap().clone();
    let n = entry.padded_size;
    let corpus = SyntheticCorpus::new(CorpusConfig::default_for_vocab(entry.vocab));
    let mut tokens = Vec::new();
    corpus.fill_train_batch(entry.batch, entry.seq_len, 3, &mut tokens);

    let flat = init_flat(&entry, 5);
    let m = vec![0.02f32; n];
    let v = vec![0.003f32; n];
    // Mask: alternate blocks of 512 lanes (covers both branches).
    let mask: Vec<f32> = (0..n).map(|i| ((i / 512) % 2 == 0) as u32 as f32).collect();
    let (lr_full, lr_free, step_t) = (1e-3f32, 4e-4f32, 3.0f32);

    // Path A: fused step artifact.
    let step_exe = rt.load(&man.artifact_path("test", "step").unwrap()).unwrap();
    let out = step_exe
        .run(&[
            lit_f32(&flat),
            lit_f32(&m),
            lit_f32(&v),
            lit_f32(&mask),
            lit_i32_2d(&tokens, entry.batch, entry.seq_len).unwrap(),
            lit_scalar1(lr_full),
            lit_scalar1(lr_free),
            lit_scalar1(step_t),
        ])
        .unwrap();
    let loss_a = to_scalar_f32(&out[0]).unwrap();
    let p_a = to_vec_f32(&out[1]).unwrap();
    let m_a = to_vec_f32(&out[2]).unwrap();
    let v_a = to_vec_f32(&out[3]).unwrap();

    // Path B: grad artifact + frugal_update kernel artifact.
    let grad_exe = rt.load(&man.artifact_path("test", "grad").unwrap()).unwrap();
    let gout = grad_exe
        .run(&[lit_f32(&flat),
               lit_i32_2d(&tokens, entry.batch, entry.seq_len).unwrap()])
        .unwrap();
    let loss_b = to_scalar_f32(&gout[0]).unwrap();
    let grads = to_vec_f32(&gout[1]).unwrap();
    // The optimizer-only artifact is built at fixed sizes; test config is
    // padded to fit in the 4096 one? If not, fall back to a rust mirror.
    assert!((loss_a - loss_b).abs() < 1e-5, "losses differ: {loss_a} vs {loss_b}");

    // Rust mirror of the kernel (same math as ref.py).
    let (b1, b2, eps, wd) =
        (entry.beta1 as f32, entry.beta2 as f32, entry.eps as f32,
         entry.weight_decay as f32);
    let bc1 = 1.0 - b1.powf(step_t);
    let bc2 = 1.0 - b2.powf(step_t);
    for i in 0..n {
        let g = grads[i];
        let nm = b1 * m[i] + (1.0 - b1) * g;
        let nv = b2 * v[i] + (1.0 - b2) * g * g;
        let on = mask[i] > 0.0;
        let adam = nm / bc1 / ((nv / bc2).sqrt() + eps) + wd * flat[i];
        let sign = if g > 0.0 { 1.0 } else if g < 0.0 { -1.0 } else { 0.0 };
        let want_p = flat[i] - if on { lr_full * adam } else { lr_free * sign };
        let want_m = if on { nm } else { 0.0 };
        let want_v = if on { nv } else { 0.0 };
        assert!((p_a[i] - want_p).abs() < 2e-5, "lane {i}: p {} vs {}", p_a[i], want_p);
        assert!((m_a[i] - want_m).abs() < 2e-5, "lane {i}: m");
        assert!((v_a[i] - want_v).abs() < 2e-5, "lane {i}: v");
    }
}

/// Optimizer-only kernel artifact matches the Rust mirror (fresh state).
#[test]
fn frugal_update_artifact_matches_rust_mirror() {
    let Some((rt, man)) = open() else { return };
    let n = 4096usize;
    let exe = rt.load(&man.optim_artifact("frugal_update_4096").unwrap()).unwrap();
    let mut rng = frugal::util::Prng::seed_from_u64(0);
    let p: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
    let g: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
    let m: Vec<f32> = (0..n).map(|_| 0.1 * rng.normal()).collect();
    let v: Vec<f32> = (0..n).map(|_| (0.01 * rng.normal()).abs()).collect();
    let mask: Vec<f32> = (0..n).map(|_| rng.bool(0.5) as u32 as f32).collect();
    let out = exe
        .run(&[lit_f32(&p), lit_f32(&g), lit_f32(&m), lit_f32(&v), lit_f32(&mask),
               lit_scalar1(1e-3), lit_scalar1(3e-4), lit_scalar1(7.0)])
        .unwrap();
    let new_p = to_vec_f32(&out[0]).unwrap();
    let bc1 = 1.0 - 0.9f32.powf(7.0);
    let bc2 = 1.0 - 0.999f32.powf(7.0);
    for i in (0..n).step_by(17) {
        let nm = 0.9 * m[i] + 0.1 * g[i];
        let nv = 0.999 * v[i] + 0.001 * g[i] * g[i];
        let want = if mask[i] > 0.0 {
            p[i] - 1e-3 * (nm / bc1) / ((nv / bc2).sqrt() + 1e-8)
        } else {
            p[i] - 3e-4 * g[i].signum()
        };
        assert!((new_p[i] - want).abs() < 1e-5, "lane {i}");
    }
}

/// A short fused training run reduces the loss on the test config.
#[test]
fn fused_training_reduces_loss() {
    let Some((rt, man)) = open() else { return };
    let entry = man.model("test").unwrap().clone();
    let corpus = SyntheticCorpus::new(CorpusConfig::default_for_vocab(entry.vocab));
    let mb = MaskBuilder::new(entry.layout(), 0.25,
                              SubspacePolicy::Blockwise(BlockPolicy::Random), 0);
    let mut tr = FusedTrainer::new(&rt, &man, "test", mb,
                                   LrSchedule::ConstantWarmup { warmup: 5 }, 2e-3, 1.0, 10, 0)
        .unwrap();
    let mut first = None;
    let mut last = 0.0;
    let mut tokens = Vec::new();
    for step in 0..40 {
        corpus.fill_train_batch(entry.batch, entry.seq_len, step, &mut tokens);
        last = tr.step(&tokens).unwrap();
        first.get_or_insert(last);
    }
    assert!(last < first.unwrap(), "loss did not decrease: {first:?} -> {last}");
}

/// A short grad-path run with the Rust FRUGAL optimizer reduces loss too.
#[test]
fn grad_training_reduces_loss() {
    let Some((rt, man)) = open() else { return };
    let entry = man.model("test").unwrap().clone();
    let corpus = SyntheticCorpus::new(CorpusConfig::default_for_vocab(entry.vocab));
    let cfg = TrainConfig { model: "test".into(), optimizer: "frugal".into(), update_freq: 10,
                            ..Default::default() };
    let layout = entry.layout();
    let opt = cfg.build_optimizer(&layout).unwrap();
    let mut tr = GradTrainer::new(&rt, &man, "test", opt,
                                  LrSchedule::ConstantWarmup { warmup: 5 }, 2e-3, 0).unwrap();
    let mut first = None;
    let mut last = 0.0;
    let mut tokens = Vec::new();
    for step in 0..40 {
        corpus.fill_train_batch(entry.batch, entry.seq_len, step, &mut tokens);
        last = tr.step(&tokens).unwrap();
        first.get_or_insert(last);
    }
    assert!(last < first.unwrap(), "loss did not decrease: {first:?} -> {last}");
}

/// The predict artifact returns (batch × vocab) logits whose causal
/// position ignores the final token.
#[test]
fn predict_artifact_shape_and_causality() {
    let Some((rt, man)) = open() else { return };
    let entry = man.model("test").unwrap().clone();
    let session = Session::open(&rt, &man, "test").unwrap();
    if session.predict_exe.is_none() {
        eprintln!("SKIP: predict artifact missing");
        return;
    }
    let flat = init_flat(&entry, 1);
    let corpus = SyntheticCorpus::new(CorpusConfig::default_for_vocab(entry.vocab));
    let mut tokens = Vec::new();
    corpus.fill_train_batch(entry.batch, entry.seq_len, 0, &mut tokens);
    let logits1 = session.predict(&flat, &tokens).unwrap();
    assert_eq!(logits1.len(), entry.batch * entry.vocab);
    // Change the last token of every row: predictions must not change.
    let mut tokens2 = tokens.clone();
    for b in 0..entry.batch {
        let idx = b * entry.seq_len + entry.seq_len - 1;
        tokens2[idx] = (tokens2[idx] + 1) % entry.vocab as i32;
    }
    let logits2 = session.predict(&flat, &tokens2).unwrap();
    for (a, b) in logits1.iter().zip(&logits2) {
        assert!((a - b).abs() < 1e-5, "prediction depends on the final token");
    }
}

/// Checkpoint round trip through the trainer state.
#[test]
fn checkpoint_roundtrip_restores_training() {
    use frugal::coordinator::checkpoint::Checkpoint;
    let Some((rt, man)) = open() else { return };
    let entry = man.model("test").unwrap().clone();
    let corpus = SyntheticCorpus::new(CorpusConfig::default_for_vocab(entry.vocab));
    let cfg = TrainConfig { model: "test".into(), optimizer: "adamw".into(),
                            ..Default::default() };
    let layout = entry.layout();
    let opt = cfg.build_optimizer(&layout).unwrap();
    let mut tr = GradTrainer::new(&rt, &man, "test", opt,
                                  LrSchedule::ConstantWarmup { warmup: 0 }, 1e-3, 0).unwrap();
    let mut tokens = Vec::new();
    for step in 0..5 {
        corpus.fill_train_batch(entry.batch, entry.seq_len, step, &mut tokens);
        tr.step(&tokens).unwrap();
    }
    let ck = Checkpoint { step: 5, sections: vec![("params".into(), tr.flat.clone())] };
    let path = std::env::temp_dir().join("frugal_integration_ck.bin");
    ck.save(&path).unwrap();
    let back = Checkpoint::load(&path).unwrap();
    assert_eq!(back.step, 5);
    assert_eq!(back.get("params").unwrap(), &tr.flat[..]);
    std::fs::remove_file(path).ok();
}
