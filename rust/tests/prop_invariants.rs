//! Property-based invariants of the coordinator and optimizer suite.
//!
//! proptest is unavailable offline, so these are seeded-sweep property
//! tests: each property is checked across many PRNG-derived cases, and a
//! failing case prints its seed for reproduction.

use frugal::coordinator::subspace::{MaskBuilder, SubspacePolicy};
use frugal::engine::{
    tree_reduce, tree_reduce_with, AdaptiveCodecController, BlockQ4Codec, CompressCfg,
    CompressMode, CompressPlan, EncodedGrad, GradCodec, Payload, ReduceTree, ShardPlan,
    SignEfCodec, TopKEfCodec,
};
use frugal::optim::frugal::BlockPolicy;
use frugal::optim::projection::randk_indices;
use frugal::optim::{Layout, Role};
use frugal::util::Prng;
use frugal::TrainConfig;

fn random_layout(rng: &mut Prng) -> Layout {
    let vocab = 16 << rng.range(0, 3);
    let d = 8 << rng.range(0, 2);
    let ff = d * 2 + 8 * rng.range(0, 3);
    let layers = 1 + rng.range(0, 4);
    Layout::synthetic(vocab, d, ff, layers)
}

fn random_grads(layout: &Layout, rng: &mut Prng) -> Vec<f32> {
    let mut g = vec![0.0f32; layout.padded_size];
    for v in g[..layout.flat_size].iter_mut() {
        *v = 0.1 * rng.normal();
    }
    g
}

/// Every mask partitions the space: role lanes all-on (default roles),
/// padding all-off, and Linear density tracks rho for fine policies.
#[test]
fn prop_mask_partition() {
    for case in 0..40u64 {
        let mut rng = Prng::seed_from_u64(case);
        let layout = random_layout(&mut rng);
        let rho = rng.f32();
        let policy = match case % 3 {
            0 => SubspacePolicy::Blockwise(BlockPolicy::Random),
            1 => SubspacePolicy::Columnwise,
            _ => SubspacePolicy::RandK,
        };
        let mut mb = MaskBuilder::new(layout.clone(), rho, policy, case);
        for _round in 0..3 {
            let mask = mb.advance();
            assert_eq!(mask.len(), layout.padded_size, "case {case}");
            for p in &layout.params {
                let lanes = &mask[p.offset..p.offset + p.numel()];
                match p.role {
                    Role::Linear => {
                        assert!(lanes.iter().all(|&m| m == 0.0 || m == 1.0), "case {case}");
                    }
                    _ => assert!(lanes.iter().all(|&m| m == 1.0), "case {case}: {}", p.name),
                }
            }
            for lane in layout.flat_size..layout.padded_size {
                assert_eq!(mask[lane], 0.0, "case {case}: padding lane {lane}");
            }
            if matches!(policy, SubspacePolicy::RandK) {
                let d = mb.linear_density(&mask);
                assert!((d - rho).abs() < 0.02, "case {case}: density {d} vs rho {rho}");
            }
        }
    }
}

/// RandK index sets are seed-reconstructible, distinct, and in range.
#[test]
fn prop_randk_determinism() {
    for case in 0..60u64 {
        let mut rng = Prng::seed_from_u64(case);
        let n = 1 + rng.range(0, 5000);
        let k = rng.range(0, n + 1);
        let a = randk_indices(n, k, case * 31 + 7);
        let b = randk_indices(n, k, case * 31 + 7);
        assert_eq!(a, b, "case {case}");
        let mut sorted = a.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), k.min(n), "case {case}: duplicates");
        assert!(sorted.iter().all(|&i| i < n), "case {case}: out of range");
    }
}

/// All optimizers leave padding lanes untouched and produce finite params.
#[test]
fn prop_optimizers_respect_padding_and_finiteness() {
    let names = ["adamw", "sgd", "signsgd", "sgdm", "lion", "adafactor", "frugal", "frugal0",
                 "frugal-svd", "frugal-random", "frugal-randk", "frugal-columnwise", "galore",
                 "galore-random", "badam", "fira", "ldadam", "adamem", "lora"];
    for (case, name) in names.iter().enumerate() {
        let mut rng = Prng::seed_from_u64(case as u64);
        let layout = random_layout(&mut rng);
        let cfg = TrainConfig {
            optimizer: name.to_string(),
            update_freq: 2,
            rho: 0.3,
            ..Default::default()
        };
        let mut opt = cfg.build_optimizer(&layout).unwrap();
        let mut p = vec![0.5f32; layout.padded_size];
        for step in 0..5 {
            let g = random_grads(&layout, &mut rng);
            opt.step(&mut p, &g, 1e-3);
            for lane in layout.flat_size..layout.padded_size {
                assert_eq!(p[lane], 0.5, "{name} step {step} moved padding");
            }
            assert!(p.iter().all(|x| x.is_finite()), "{name} step {step} non-finite");
        }
    }
}

/// FRUGAL's measured state allocation matches the analytic model
/// 2·ρ·P_linear + 2·P_nonlinear (blockwise granularity slack allowed).
#[test]
fn prop_frugal_state_matches_analytic() {
    for case in 0..20u64 {
        let mut rng = Prng::seed_from_u64(1000 + case);
        let layout = random_layout(&mut rng);
        let rho = [0.0f32, 0.25, 0.5, 1.0][case as usize % 4];
        let cfg = TrainConfig {
            optimizer: "frugal-randk".into(), // exact-rho projection
            rho: rho as f64,
            ..Default::default()
        };
        let mut opt = cfg.build_optimizer(&layout).unwrap();
        let g = random_grads(&layout, &mut rng);
        let mut p = vec![0.0f32; layout.padded_size];
        opt.step(&mut p, &g, 1e-3);
        let p_nl: usize = layout
            .params
            .iter()
            .filter(|p| p.role != Role::Linear)
            .map(|p| p.numel())
            .sum();
        let expect = 2.0 * p_nl as f64 + 2.0 * rho as f64 * layout.linear_numel() as f64;
        let got = opt.state_floats() as f64;
        assert!(
            (got - expect).abs() <= 0.02 * expect + 16.0,
            "case {case} rho={rho}: state {got} vs analytic {expect}"
        );
    }
}

/// Subspace reset invariant: after the mask changes, previously-active
/// lanes that became inactive carry zero state (checked through the
/// fused-kernel reference semantics in optim::Frugal).
#[test]
fn prop_state_reset_iff_subspace_change() {
    use frugal::optim::frugal::{Frugal, FrugalCfg};
    use frugal::optim::Optimizer;
    for case in 0..10u64 {
        let mut rng = Prng::seed_from_u64(2000 + case);
        let layout = random_layout(&mut rng);
        let cfg = FrugalCfg { update_freq: 3, rho: 0.4, seed: case, ..Default::default() };
        let mut opt = Frugal::new(layout.clone(), cfg);
        let mut p = vec![0.0f32; layout.padded_size];
        for _ in 0..9 {
            let g = random_grads(&layout, &mut rng);
            opt.step(&mut p, &g, 1e-3);
            let realized = opt.realized_rho();
            assert!((realized - 0.4).abs() < 0.45, "case {case}: rho drifted to {realized}");
        }
    }
}

/// The engine's tree all-reduce is bit-identical for every leaf arrival
/// order — the invariant behind `workers=1 ≡ workers=N`. The in-order
/// sequential feed (`tree_reduce`) is the reference result.
#[test]
fn prop_tree_allreduce_arrival_order_invariant() {
    for case in 0..40u64 {
        let mut rng = Prng::seed_from_u64(case);
        let n = 1 + rng.range(0, 33);
        let len = 1 + rng.range(0, 200);
        let leaves: Vec<Vec<f32>> =
            (0..n).map(|_| (0..len).map(|_| rng.normal()).collect()).collect();
        let want: Vec<u32> =
            tree_reduce(leaves.clone()).iter().map(|x| x.to_bits()).collect();
        for _ in 0..4 {
            let mut order: Vec<usize> = (0..n).collect();
            rng.shuffle(&mut order);
            let mut tree = ReduceTree::new(n);
            let mut root = None;
            for &i in &order {
                if let Some(r) = tree.push(i, leaves[i].clone()) {
                    root = Some(r);
                }
            }
            let got: Vec<u32> =
                root.expect("tree incomplete").iter().map(|x| x.to_bits()).collect();
            assert_eq!(got, want, "case {case}: order {order:?}");
        }
    }
}

/// On integer-valued leaves (exact in f32) the tree sum equals the naive
/// sequential sum exactly — nothing is dropped or double-counted.
#[test]
fn prop_tree_allreduce_exact_on_integers() {
    for case in 0..30u64 {
        let mut rng = Prng::seed_from_u64(500 + case);
        let n = 1 + rng.range(0, 20);
        let len = 1 + rng.range(0, 50);
        let leaves: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..len).map(|_| rng.range(0, 200) as f32 - 100.0).collect())
            .collect();
        let mut naive = vec![0.0f32; len];
        for leaf in &leaves {
            for (a, b) in naive.iter_mut().zip(leaf) {
                *a += b;
            }
        }
        assert_eq!(tree_reduce(leaves), naive, "case {case}");
    }
}

/// The encoded-payload tree (decode-combine-reencode through the round's
/// compression plan) is bit-invariant to arrival-order permutation and
/// worker count for every codec — the compression extension of the
/// `workers=1 ≡ workers=N` invariant. Worker counts are exercised as
/// round-robin arrival patterns (worker w owns slots w, w+N, ...; one
/// worker races arbitrarily far ahead, and the reverse), plus random
/// shuffles.
#[test]
fn prop_encoded_tree_arrival_and_worker_count_invariant() {
    for case in 0..28u64 {
        let mut rng = Prng::seed_from_u64(4000 + case);
        let mode = CompressMode::ALL[case as usize % CompressMode::ALL.len()];
        let flat = 32 + rng.range(0, 400);
        let padded = flat + rng.range(0, 32);
        let mut full = Vec::new();
        let mut free = Vec::new();
        for i in 0..flat as u32 {
            if rng.f32() < 0.4 {
                full.push(i);
            } else {
                free.push(i);
            }
        }
        let cfg = CompressCfg { mode, block: 1 + rng.range(0, 100) };
        let plan = CompressPlan::new(cfg, full, free, padded);
        let m = 1 + rng.range(0, 12);
        let leaves: Vec<EncodedGrad> = (0..m)
            .map(|_| {
                let grad: Vec<f32> = (0..padded)
                    .map(|i| if i < flat { 0.1 * rng.normal() } else { 0.0 })
                    .collect();
                plan.encode_leaf(grad, None).expect("finite grads encode").0
            })
            .collect();
        let want: Vec<u32> = plan
            .into_grad(tree_reduce_with(leaves.clone(), |a, b| plan.combine(a, b)))
            .iter()
            .map(|x| x.to_bits())
            .collect();
        let mut orders: Vec<Vec<usize>> = Vec::new();
        for workers in [1usize, 2, 3, 8] {
            // Worker w owns slots {w, w+N, ...}. Emit each worker's whole
            // stream before the next worker's — the arrival pattern of one
            // worker racing arbitrarily far ahead — and its reverse.
            let mut order = Vec::new();
            for w in 0..workers {
                let mut j = w;
                while j < m {
                    order.push(j);
                    j += workers;
                }
            }
            let mut rev = order.clone();
            rev.reverse();
            orders.push(order);
            orders.push(rev);
        }
        for _ in 0..3 {
            let mut order: Vec<usize> = (0..m).collect();
            rng.shuffle(&mut order);
            orders.push(order);
        }
        for order in orders {
            let mut tree = ReduceTree::new(m);
            let mut root = None;
            for &i in &order {
                if let Some(r) =
                    tree.push_with(i, leaves[i].clone(), &mut |a, b| plan.combine(a, b))
                {
                    root = Some(r);
                }
            }
            let got: Vec<u32> = plan
                .into_grad(root.expect("tree incomplete"))
                .iter()
                .map(|x| x.to_bits())
                .collect();
            assert_eq!(got, want, "case {case} mode {mode:?} order {order:?}");
        }
    }
}

/// SignEf encode→decode round-trips sign and block scale exactly: every
/// decoded lane is bitwise ±(block's mean |value|) with the input's sign
/// (zero counted positive).
#[test]
fn prop_sign_ef_roundtrip_exact() {
    for case in 0..30u64 {
        let mut rng = Prng::seed_from_u64(6000 + case);
        let n = 1 + rng.range(0, 300);
        let block = 1 + rng.range(0, 64);
        let vals: Vec<f32> = (0..n).map(|_| 0.3 * rng.normal()).collect();
        let codec = SignEfCodec { block };
        let payload = codec.encode(&vals, None);
        let dec = codec.decode(&payload);
        assert_eq!(dec.len(), n, "case {case}");
        for (b, blk) in vals.chunks(block).enumerate() {
            let mut sum = 0.0f32;
            for &x in blk {
                sum += x.abs();
            }
            let scale = sum / blk.len() as f32;
            for (k, &x) in blk.iter().enumerate() {
                let want = if x >= 0.0 { scale } else { -scale };
                assert_eq!(
                    dec[b * block + k].to_bits(),
                    want.to_bits(),
                    "case {case} lane {}",
                    b * block + k
                );
            }
        }
    }
}

/// Shard partitions cover every lane exactly once, in order, with the
/// per-shard size bounded by ceil(K/N) rounded up to the granularity.
#[test]
fn prop_shard_partition_covers_and_bounds() {
    for case in 0..50u64 {
        let mut rng = Prng::seed_from_u64(900 + case);
        let k = rng.range(0, 5000);
        let workers = 1 + rng.range(0, 9);
        let gran = 1 + rng.range(0, 128);
        let mut lanes: Vec<u32> = (0..k as u32).map(|i| i * 2 + 1).collect();
        rng.shuffle(&mut lanes);
        let plan = ShardPlan::partition(lanes.clone(), workers, gran);
        lanes.sort_unstable();
        let mut recovered = Vec::new();
        for w in 0..workers {
            recovered.extend_from_slice(plan.lanes_of(w));
        }
        assert_eq!(recovered, lanes, "case {case}: lanes lost or reordered");
        let ceil = if k == 0 { 0 } else { (k + workers - 1) / workers };
        let bound = (ceil + gran - 1) / gran * gran;
        assert!(
            plan.max_shard_len() <= bound.max(1),
            "case {case}: shard {} > bound {bound} (K={k} N={workers} gran={gran})",
            plan.max_shard_len()
        );
    }
}

/// LR schedules stay in (0, 1] for any step.
#[test]
fn prop_schedule_bounds() {
    use frugal::coordinator::LrSchedule;
    for case in 0..30u64 {
        let mut rng = Prng::seed_from_u64(case);
        let total = 10 + rng.range(0, 5000) as u64;
        let warmup = rng.range(0, (total / 2) as usize) as u64;
        let scheds = [
            LrSchedule::ConstantWarmup { warmup },
            LrSchedule::Cosine { total, warmup, min_frac: 0.1 },
            LrSchedule::CosineRestarts { cycle: total, warmup_frac: 0.1, min_frac: 0.1 },
        ];
        for s in &scheds {
            for _ in 0..50 {
                let step = rng.range(0, 3 * total as usize) as u64;
                let f = s.factor(step);
                assert!(f > 0.0 && f <= 1.0 + 1e-12, "case {case} {s:?} step {step}: {f}");
            }
        }
    }
}

/// bf16 rounding is idempotent and monotone-bounded.
#[test]
fn prop_bf16_round() {
    use frugal::tensor::bf16_round;
    let mut rng = Prng::seed_from_u64(7);
    for _ in 0..5000 {
        let x = rng.normal() * 10f32.powi(rng.range(0, 8) as i32 - 4);
        let r = bf16_round(x);
        assert_eq!(bf16_round(r), r, "not idempotent at {x}");
        if x != 0.0 {
            assert!(((r - x) / x).abs() <= 1.0 / 256.0 + 1e-7, "error too big at {x} -> {r}");
        }
    }
}

/// Jacobi SVD reconstructs random matrices across shapes.
#[test]
fn prop_svd_reconstruction() {
    use frugal::linalg::svd;
    use frugal::tensor::Matrix;
    for case in 0..25u64 {
        let mut rng = Prng::seed_from_u64(case);
        let m = 1 + rng.range(0, 24);
        let n = 1 + rng.range(0, 24);
        let a = Matrix::from_fn(m, n, |_, _| rng.normal());
        let d = svd(&a);
        // Reconstruct.
        let k = d.s.len();
        let mut rec = Matrix::zeros(m, n);
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0;
                for l in 0..k {
                    acc += d.u[(i, l)] * d.s[l] * d.v[(j, l)];
                }
                rec[(i, j)] = acc;
            }
        }
        let err = a.sub(&rec).frobenius_norm();
        let scale = a.frobenius_norm().max(1e-6);
        assert!(err / scale < 1e-3, "case {case} ({m}x{n}): err {err}");
    }
}

/// The corpus stream is reproducible and respects the vocab bound for any
/// seed/shape combination.
#[test]
fn prop_corpus_stream() {
    use frugal::data::{CorpusConfig, SyntheticCorpus};
    for case in 0..10u64 {
        let mut rng = Prng::seed_from_u64(case);
        let vocab = 32 << rng.range(0, 4);
        let mut cfg = CorpusConfig::default_for_vocab(vocab);
        cfg.seed = case;
        let c1 = SyntheticCorpus::new(cfg.clone());
        let c2 = SyntheticCorpus::new(cfg);
        let (mut b1, mut b2) = (Vec::new(), Vec::new());
        c1.fill_train_batch(2, 64, case, &mut b1);
        c2.fill_train_batch(2, 64, case, &mut b2);
        assert_eq!(b1, b2, "case {case}");
        assert!(b1.iter().all(|&t| (t as usize) < vocab), "case {case}");
    }
}

/// Arbitrary ρ-schedules are valid, canonical-spec round-trippable, and
/// bounded: rho_at ∈ [0, 1] everywhere, parse(display(s)) reproduces
/// rho_at bit-for-bit (the spec string is the checkpoint fingerprint, so
/// this IS the resume invariant), and decaying parameterizations are
/// monotone non-increasing.
#[test]
fn prop_rho_schedules_roundtrip_and_bound() {
    use frugal::schedule::RhoSchedule;
    for case in 0..40u64 {
        let mut rng = Prng::seed_from_u64(8000 + case);
        let hi = 0.2 + 0.8 * rng.f64();
        let lo = rng.f64() * hi;
        let epochs = 1 + rng.range(0, 12) as u64;
        let sched = match case % 4 {
            0 => RhoSchedule::Constant { rho: hi },
            1 => RhoSchedule::Linear { start: hi, end: lo, epochs },
            2 => RhoSchedule::Cosine { start: hi, end: lo, epochs },
            _ => RhoSchedule::Step {
                start: hi,
                factor: 0.3 + 0.7 * rng.f64(),
                every: 1 + rng.range(0, 4) as u64,
                min: lo,
            },
        };
        sched.validate().unwrap_or_else(|e| panic!("case {case}: {e}"));
        let back = RhoSchedule::parse(&format!("{sched}"))
            .unwrap_or_else(|e| panic!("case {case}: {e}"));
        let mut prev = f64::INFINITY;
        for epoch in 0..3 * epochs + 4 {
            let r = sched.rho_at(epoch);
            assert!((0.0..=1.0).contains(&r), "case {case} epoch {epoch}: {r}");
            assert_eq!(
                back.rho_at(epoch).to_bits(),
                r.to_bits(),
                "case {case} epoch {epoch}: spec roundtrip drifted"
            );
            // All four kinds here decay (start >= end/min by
            // construction): non-increasing everywhere.
            assert!(r <= prev + 1e-15, "case {case} epoch {epoch}: {r} > {prev}");
            prev = r;
        }
    }
}

/// Elastic re-provisioning invariants under arbitrary decaying
/// ρ-schedules: (a) each epoch's mask width matches K(epoch) — the
/// RandK policy realizes round(rho·n) per Linear param exactly; (b) the
/// state-full/state-free lane sets partition the real lanes and both
/// shard plans partition their sets exactly, at every worker count.
#[test]
fn prop_variable_rho_masks_and_shard_plans() {
    use frugal::coordinator::subspace::lane_partition;
    use frugal::schedule::RhoSchedule;
    for case in 0..12u64 {
        let mut rng = Prng::seed_from_u64(8600 + case);
        let layout = random_layout(&mut rng);
        let hi = 0.3 + 0.7 * rng.f64();
        let lo = rng.f64() * hi;
        let epochs = 1 + rng.range(0, 5) as u64;
        let sched = if case % 2 == 0 {
            RhoSchedule::Linear { start: hi, end: lo, epochs }
        } else {
            RhoSchedule::Cosine { start: hi, end: lo, epochs }
        };
        let mut mb = MaskBuilder::with_schedule(
            layout.clone(),
            sched.clone(),
            SubspacePolicy::RandK,
            case,
        );
        let workers = 1 + rng.range(0, 6);
        let gran = 1 + rng.range(0, 64);
        for epoch in 0..6u64 {
            let mask = mb.advance();
            // (a) Mask width = K(epoch): role lanes plus the per-param
            // RandK pick count at this epoch's scheduled density.
            let rho_e = sched.rho_at(epoch) as f32;
            let mut want_linear = 0usize;
            let mut role_lanes = 0usize;
            for p in &layout.params {
                if p.role == frugal::optim::Role::Linear {
                    let n = p.numel();
                    want_linear += ((rho_e * n as f32).round() as usize).min(n);
                } else {
                    role_lanes += p.numel();
                }
            }
            let (full, free) = lane_partition(&mask, layout.flat_size);
            assert_eq!(
                full.len(),
                role_lanes + want_linear,
                "case {case} epoch {epoch}: K mismatch at rho {rho_e}"
            );
            // (b) Partition exactness: full ∪ free = real lanes, and
            // each shard plan tiles its lane set in order.
            assert_eq!(full.len() + free.len(), layout.flat_size, "case {case}");
            for lanes in [&full, &free] {
                let plan = ShardPlan::partition(lanes.clone(), workers, gran);
                let mut recovered = Vec::new();
                for w in 0..workers {
                    recovered.extend_from_slice(plan.lanes_of(w));
                }
                assert_eq!(&recovered, lanes, "case {case} epoch {epoch}");
            }
        }
    }
}

/// Membership transitions never corrupt the rank space or the shard
/// tiling. The model mirrors the coordinator's compaction exactly:
/// joiners are admitted at the tail (ids handed out monotonically),
/// leavers/evictees are removed in place so later ranks shift down.
/// Across arbitrary join/leave/evict sequences the survivor list must
/// stay duplicate-free, gapless (rank = index), and admission-ordered —
/// and re-partitioning the lane set over any survivor count must yield
/// shards that tile it exactly: sorted, disjoint, complete.
#[test]
fn prop_membership_transitions_preserve_ranks_and_shard_tiling() {
    for case in 0..60u64 {
        let mut rng = Prng::seed_from_u64(0xC0FF_EE ^ case);
        let mut members: Vec<u64> = Vec::new();
        let mut next_id = 0u64;
        for _ in 0..1 + rng.range(0, 4) {
            members.push(next_id);
            next_id += 1;
        }
        // The lane space being sharded, with duplicates and shuffling —
        // partition() must canonicalize before cutting.
        let mut lanes: Vec<u32> = (0..1 + rng.range(0, 4000) as u32).collect();
        for _ in 0..rng.range(0, 50) {
            let i = rng.range(0, lanes.len());
            let dup = lanes[i];
            lanes.push(dup);
        }
        for i in (1..lanes.len()).rev() {
            let j = rng.range(0, i + 1);
            lanes.swap(i, j);
        }
        let granularity = 1usize << rng.range(0, 8);

        for transition in 0..1 + rng.range(0, 20) {
            match rng.range(0, 3) {
                0 => {
                    // Join: admitted at the next round boundary, tail rank.
                    members.push(next_id);
                    next_id += 1;
                }
                _ if members.len() > 1 => {
                    // Leave or evict: removed in place (rank compaction).
                    let gone = rng.range(0, members.len());
                    members.remove(gone);
                }
                _ => {}
            }
            // Ranks: unique, gapless by construction (rank = index), and
            // admission-ordered — monotone ids prove order stability.
            let mut ids = members.clone();
            ids.sort_unstable();
            ids.dedup();
            assert_eq!(
                ids.len(),
                members.len(),
                "case {case} transition {transition}: duplicate members"
            );
            assert!(
                members.windows(2).all(|w| w[0] < w[1]),
                "case {case} transition {transition}: compaction broke admission order"
            );

            // Shard tiling at the new survivor count.
            let plan = ShardPlan::partition(lanes.clone(), members.len(), granularity);
            assert_eq!(plan.workers(), members.len(), "case {case}");
            let mut covered: Vec<u32> = Vec::new();
            for w in 0..plan.workers() {
                let shard = plan.lanes_of(w);
                assert!(
                    shard.windows(2).all(|x| x[0] < x[1]),
                    "case {case} transition {transition}: shard {w} unsorted/duplicated"
                );
                assert_eq!(shard.len(), plan.shard_len(w), "case {case}");
                covered.extend_from_slice(shard);
            }
            let mut want = lanes.clone();
            want.sort_unstable();
            want.dedup();
            assert_eq!(
                covered, want,
                "case {case} transition {transition}: shards do not tile the lane space"
            );
            assert_eq!(plan.total_lanes(), want.len(), "case {case}");
        }
    }
}

/// The supervised-respawn backoff schedule is a pure function of the
/// attempt index: deterministic, monotone non-decreasing, and capped at
/// 32× the base so a crash-looping worker cannot push retries out to
/// infinity.
#[test]
fn prop_respawn_backoff_deterministic_monotone_capped() {
    use std::time::Duration;
    for case in 0..40u64 {
        let mut rng = Prng::seed_from_u64(case);
        let base = 1 + rng.range(0, 2000) as u64;
        let fault = frugal::engine::FaultCfg { respawn_backoff_ms: base, ..Default::default() };
        let mut prev = Duration::ZERO;
        for attempt in 0..12u32 {
            let d = fault.respawn_delay(attempt);
            assert_eq!(d, fault.respawn_delay(attempt), "case {case}: nondeterministic");
            assert!(d >= prev, "case {case} attempt {attempt}: backoff shrank");
            assert!(
                d <= Duration::from_millis(base.saturating_mul(32)),
                "case {case} attempt {attempt}: cap exceeded ({d:?})"
            );
            prev = d;
        }
        // Past the cap the schedule is flat.
        assert_eq!(fault.respawn_delay(5), fault.respawn_delay(11), "case {case}");
    }
}

/// TopKEf ships exact (index, value) pairs: the payload holds exactly
/// `k_for(n)` strictly-ascending indices, every selected lane decodes
/// bitwise to the EF signal `v + r`, every unselected lane decodes to
/// 0, and the residual after encode is `0` on selected lanes and
/// `r + v` on the rest — the codec's whole error budget lives in the
/// residual, never in the transmitted values.
#[test]
fn prop_topk_ef_roundtrip_exact() {
    for case in 0..30u64 {
        let mut rng = Prng::seed_from_u64(7000 + case);
        let n = 1 + rng.range(0, 300);
        let k_permille = 1 + rng.range(0, 400) as u16;
        let codec = TopKEfCodec { k_permille };
        let vals: Vec<f32> = (0..n).map(|_| 0.3 * rng.normal()).collect();
        let mut residual: Vec<f32> = (0..n).map(|_| 0.05 * rng.normal()).collect();
        let r_before = residual.clone();
        let payload = codec.encode(&vals, Some(&mut residual));
        let Payload::TopK { len, ref idx, vals: ref sel } = payload else {
            panic!("case {case}: TopKEf produced a non-TopK payload");
        };
        assert_eq!(len, n, "case {case}");
        assert_eq!(idx.len(), codec.k_for(n), "case {case}: wrong k");
        assert_eq!(sel.len(), idx.len(), "case {case}");
        assert!(
            idx.windows(2).all(|w| w[0] < w[1]),
            "case {case}: indices not strictly ascending"
        );
        let dec = codec.decode(&payload);
        assert_eq!(dec.len(), n, "case {case}");
        let mut selected = vec![false; n];
        for (&i, &s) in idx.iter().zip(sel) {
            selected[i as usize] = true;
            let e = vals[i as usize] + r_before[i as usize];
            assert_eq!(s.to_bits(), e.to_bits(), "case {case} lane {i}: shipped value inexact");
        }
        for i in 0..n {
            if selected[i] {
                assert_eq!(
                    dec[i].to_bits(),
                    (vals[i] + r_before[i]).to_bits(),
                    "case {case} lane {i}: selected lane decoded inexactly"
                );
                assert_eq!(residual[i].to_bits(), 0.0f32.to_bits(), "case {case} lane {i}");
            } else {
                assert_eq!(dec[i].to_bits(), 0.0f32.to_bits(), "case {case} lane {i}");
                assert_eq!(
                    residual[i].to_bits(),
                    (r_before[i] + vals[i]).to_bits(),
                    "case {case} lane {i}: residual lost signal"
                );
            }
        }
    }
}

/// TopKEf error feedback is unbiased in the long run: over many steps
/// the per-lane invariant `Σ decoded + residual = Σ signal` holds (the
/// residual is the only place error accumulates, and every selection
/// flushes it exactly), so the accumulated transmission tracks the
/// accumulated signal to float-accumulation precision on every lane —
/// including lanes far too small to ever win a single round.
#[test]
fn prop_topk_ef_long_run_unbiased() {
    for case in 0..12u64 {
        let mut rng = Prng::seed_from_u64(7500 + case);
        let n = 8 + rng.range(0, 120);
        let codec = TopKEfCodec { k_permille: 1 + rng.range(0, 80) as u16 };
        let steps = 400;
        let mut residual = vec![0.0f32; n];
        let mut acc_dec = vec![0.0f64; n];
        let mut acc_sig = vec![0.0f64; n];
        // Per-lane magnitude spread of ~100x so small lanes must wait
        // many rounds for their residual to win selection.
        let mags: Vec<f32> = (0..n).map(|_| 0.01 * (1.0 + 99.0 * rng.f32())).collect();
        for _ in 0..steps {
            let vals: Vec<f32> = mags.iter().map(|&m| m * rng.normal()).collect();
            let payload = codec.encode(&vals, Some(&mut residual));
            for (a, &d) in acc_dec.iter_mut().zip(&codec.decode(&payload)) {
                *a += f64::from(d);
            }
            for (a, &v) in acc_sig.iter_mut().zip(&vals) {
                *a += f64::from(v);
            }
        }
        for i in 0..n {
            let gap = (acc_dec[i] + f64::from(residual[i]) - acc_sig[i]).abs();
            // Only fp32-accumulation noise is allowed; the EF identity
            // itself is exact per step.
            let tol = 1e-3 * (1.0 + acc_sig[i].abs());
            assert!(
                gap <= tol,
                "case {case} lane {i}: EF leaked signal (gap {gap}, tol {tol})"
            );
        }
    }
}

/// BlockQ4 decode error is bounded by half a quantization step per
/// lane: `|dec - v| ≤ amax/14` for every normal-scale block (scale =
/// amax/7, 15 signed levels), and flushed (zero/subnormal-absmax)
/// blocks decode to exact zeros with error ≤ amax, which is itself
/// below float-noise scale.
#[test]
fn prop_q4_decode_within_half_step() {
    for case in 0..30u64 {
        let mut rng = Prng::seed_from_u64(8000 + case);
        let n = 1 + rng.range(0, 300);
        let block = 1 + rng.range(0, 64);
        let codec = BlockQ4Codec { block };
        let mag = [1.0f32, 1e-3, 1e3][case as usize % 3];
        let mut vals: Vec<f32> = (0..n).map(|_| mag * rng.normal()).collect();
        // Force some all-zero blocks to exercise the flush arm.
        if n > block && rng.f32() < 0.5 {
            for v in vals[..block].iter_mut() {
                *v = 0.0;
            }
        }
        let payload = codec.encode(&vals, None);
        let dec = codec.decode(&payload);
        assert_eq!(dec.len(), n, "case {case}");
        for (b, blk) in vals.chunks(block).enumerate() {
            let mut amax = 0.0f32;
            for &x in blk {
                amax = amax.max(x.abs());
            }
            // Half-step plus fp slop; the absolute term covers flushed
            // subnormal-absmax blocks (amax < 8.3e-38 there).
            let bound = 0.5001 * amax / 7.0 + 1e-37;
            for (k, &x) in blk.iter().enumerate() {
                let d = dec[b * block + k];
                assert!(
                    (d - x).abs() <= bound,
                    "case {case} lane {}: |{d} - {x}| > {bound} (amax {amax})",
                    b * block + k
                );
            }
        }
    }
}

/// The adaptive controller is a pure function of the deterministic
/// counter trace: sharding the same leaf signals across 1 vs 4 workers
/// produces identical u64 totals, hence identical codec choices,
/// history fingerprints, and marks at every epoch — and a controller
/// rebuilt mid-run from `history_string()` + `marks()` (resume)
/// continues bit-identically to the uninterrupted one.
#[test]
fn prop_adaptive_controller_deterministic_and_resumable() {
    for case in 0..20u64 {
        let mut rng = Prng::seed_from_u64(9000 + case);
        let budget = 5 + rng.range(0, 60) as u16;
        let mut solo = AdaptiveCodecController::new(budget);
        let mut fleet = AdaptiveCodecController::new(budget);
        let mut resumed: Option<AdaptiveCodecController> = None;
        let (mut free_total, mut full_total, mut leaves_total) = (0u64, 0u64, 0u64);
        let epochs = 6 + rng.range(0, 6) as u64;
        for epoch in 1..=epochs {
            // Per-leaf signals for this epoch (millionths, as produced
            // by LeafSignal). Magnitudes drift upward so later epochs
            // can trip rung climbs.
            let leaves = 4 + rng.range(0, 12);
            let sigs: Vec<(u64, u64)> = (0..leaves)
                .map(|_| {
                    let drift = epoch * rng.range(0, 200_000) as u64 / epochs;
                    (
                        (900_000 + rng.range(0, 100_000) as u64 + drift).min(1_000_000),
                        (rng.range(0, 120_000) as u64 + drift).min(1_000_000),
                    )
                })
                .collect();
            // Worker 1: one stream, in slot order. Workers 4: four
            // round-robin shards summed shard-by-shard. u64 addition
            // commutes, so the totals must match bitwise.
            let (mut f1, mut u1) = (0u64, 0u64);
            for &(f, u) in &sigs {
                f1 += f;
                u1 += u;
            }
            let (mut f4, mut u4) = (0u64, 0u64);
            for w in 0..4usize {
                let mut j = w;
                while j < sigs.len() {
                    f4 += sigs[j].0;
                    u4 += sigs[j].1;
                    j += 4;
                }
            }
            assert_eq!((f1, u1), (f4, u4), "case {case} epoch {epoch}: shard sums diverge");
            free_total += f1;
            full_total += u1;
            leaves_total += leaves as u64;
            let c1 = solo.observe_epoch(epoch, free_total, full_total, leaves_total);
            let c4 = fleet.observe_epoch(epoch, free_total, full_total, leaves_total);
            assert_eq!(c1, c4, "case {case} epoch {epoch}: change flags diverge");
            assert_eq!(
                solo.assignment(),
                fleet.assignment(),
                "case {case} epoch {epoch}: workers 1 vs 4 picked different codecs"
            );
            assert_eq!(solo.history_string(), fleet.history_string(), "case {case}");
            assert_eq!(solo.marks(), fleet.marks(), "case {case} epoch {epoch}");
            if let Some(r) = resumed.as_mut() {
                r.observe_epoch(epoch, free_total, full_total, leaves_total);
                assert_eq!(
                    r.history_string(),
                    solo.history_string(),
                    "case {case} epoch {epoch}: resume ≢ continuous"
                );
                assert_eq!(r.assignment(), solo.assignment(), "case {case} epoch {epoch}");
            }
            // Checkpoint/restore at mid-run: rebuild from the
            // fingerprint + marks and run it alongside from here on.
            if epoch == epochs / 2 {
                let mut r = AdaptiveCodecController::from_history(budget, &solo.history_string())
                    .expect("fingerprint round-trips");
                r.restore_marks(solo.marks());
                assert_eq!(r.assignment(), solo.assignment(), "case {case}: restore mismatch");
                resumed = Some(r);
            }
        }
    }
}
