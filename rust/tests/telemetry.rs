//! Integration tests for the unified telemetry subsystem (ISSUE 6).
//!
//! The headline invariants:
//! - the **deterministic counter plane** of the manifest is bit-identical
//!   at `workers 1` vs `workers 4` (compress none and split) and across a
//!   kill/resume at a save barrier vs the uninterrupted run;
//! - counters **continue** (not restart) across a resume — totals are
//!   strictly monotone over the restored values;
//! - the three wire-byte surfaces (engine total, per-round
//!   `RoundReport.wire_bytes`, captured `TrainState.wire_bytes`) agree
//!   after a multi-round run, because all of them read the one registry
//!   counter.

use std::path::PathBuf;

use frugal::ckpt::{self, MomentCodec, SaveOptions};
use frugal::coordinator::subspace::{MaskBuilder, SubspacePolicy};
use frugal::coordinator::LrSchedule;
use frugal::engine::{
    CompressCfg, CompressMode, Engine, EngineCfg, GradSource, ParallelCfg, RefLm, RefLmCfg,
    Sources,
};
use frugal::optim::adamw::AdamCfg;
use frugal::optim::frugal::BlockPolicy;
use frugal::telemetry::{Counter, Phase, DET_COUNTERS};
use frugal::util::json::Json;

const SEED: u64 = 42;
const UPDATE_FREQ: u64 = 4;
const GRAD_ACCUM: usize = 4;

fn engine(workers: usize, mode: CompressMode) -> Engine {
    let m = RefLm::new(RefLmCfg::default());
    let layout = m.layout().clone();
    let sources = Sources::Threaded(
        (0..workers).map(|_| Box::new(m.clone()) as Box<dyn GradSource + Send>).collect(),
    );
    let mask_builder =
        MaskBuilder::new(layout, 0.25, SubspacePolicy::Blockwise(BlockPolicy::Random), SEED);
    let cfg = EngineCfg {
        parallel: ParallelCfg {
            workers,
            grad_accum: GRAD_ACCUM,
            compress: CompressCfg { mode, block: 64 },
            ..Default::default()
        },
        schedule: LrSchedule::ConstantWarmup { warmup: 2 },
        peak_lr: 1e-3,
        lr_free_mult: 1.0,
        update_freq: UPDATE_FREQ,
        adam: AdamCfg::default(),
        clip: None,
    };
    Engine::builder()
        .mask_builder(mask_builder)
        .cfg(cfg)
        .sources(sources)
        .init_flat(m.init_flat(SEED))
        .build()
        .unwrap()
}

fn batch_fn(micro: u64, buf: &mut Vec<i32>) {
    let cfg = RefLmCfg::default();
    let mut rng = frugal::util::Prng::seed_from_u64(0xC4A7 ^ micro.wrapping_mul(0x9E37));
    buf.clear();
    buf.extend((0..cfg.batch * cfg.seq_len).map(|_| rng.range(0, cfg.vocab) as i32));
}

fn run(engine: &mut Engine, steps: u64) {
    for _ in 0..steps {
        engine.step(&batch_fn).unwrap();
    }
}

fn tmpdir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("frugal_tel_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

/// The `.deterministic` plane of a manifest, parsed (CI jq-diffs the same
/// object; HashMap equality is order-insensitive content equality).
fn det_plane(manifest: &str) -> Json {
    Json::parse(manifest).unwrap().field("deterministic").unwrap().clone()
}

/// Acceptance criterion: the deterministic counter plane is bit-identical
/// at workers 1 vs 4, for compress none and split — both as raw words and
/// through the canonical JSON manifest.
#[test]
fn deterministic_plane_is_identical_across_worker_counts() {
    for mode in [CompressMode::None, CompressMode::Split] {
        let mut one = engine(1, mode);
        let mut four = engine(4, mode);
        run(&mut one, 10);
        run(&mut four, 10);
        assert_eq!(
            one.telemetry().deterministic_words(),
            four.telemetry().deterministic_words(),
            "{mode:?}: deterministic words diverged between workers 1 and 4"
        );
        assert_eq!(
            det_plane(&one.telemetry().manifest_json()),
            det_plane(&four.telemetry().manifest_json()),
            "{mode:?}: manifest .deterministic diverged"
        );
        // Sanity: the run actually metered something on every counter
        // that must move in steady state.
        let t = one.telemetry();
        assert_eq!(t.get(Counter::Steps), 10);
        assert_eq!(t.get(Counter::MicroBatches), 10 * GRAD_ACCUM as u64);
        assert_eq!(t.get(Counter::EncodeLeafCalls), t.get(Counter::MicroBatches));
        assert_eq!(t.get(Counter::DecodeRootCalls), 10);
        assert_eq!(t.get(Counter::PoolGrabs), t.get(Counter::MicroBatches));
        assert!(t.get(Counter::WireBytes) > 0);
        assert!(t.get(Counter::WireMessages) >= t.get(Counter::MicroBatches));
        // grad_accum=4 leaves reduce through 3 interior combines per step.
        assert_eq!(t.get(Counter::CombineCalls), 10 * (GRAD_ACCUM as u64 - 1));
        // Rounds at T=4 over 10 steps: re-provisioned at steps 1, 5, 9.
        assert_eq!(t.get(Counter::Reprovisions), 3);
        if mode == CompressMode::Split {
            assert!(t.get(Counter::EfResets) > 0, "split runs EF");
            let full = t.get(Counter::WireFullBytes);
            let free = t.get(Counter::WireFreeBytes);
            assert_eq!(full + free, t.get(Counter::WireBytes), "lane-group split must partition");
        } else {
            assert_eq!(t.get(Counter::EfResets), 0);
            assert_eq!(t.get(Counter::WireFullBytes), 0, "dense messages have no groups");
        }
    }
}

/// Acceptance criterion: kill at a save barrier, resume — the resumed
/// run's deterministic plane bitwise-matches the uninterrupted run, and
/// every counter continued monotonically from its restored value.
#[test]
fn deterministic_plane_survives_kill_and_resume() {
    for mode in [CompressMode::None, CompressMode::Split] {
        let mut continuous = engine(1, mode);
        run(&mut continuous, 16);

        let mut first = engine(4, mode);
        run(&mut first, 8); // round barrier at T=4
        let st = first.capture_state().unwrap();
        assert_eq!(st.telemetry.len(), DET_COUNTERS, "capture persists the full det plane");
        let dir = tmpdir(&format!("resume_{mode}"));
        ckpt::save(&dir, &st, SaveOptions::new(MomentCodec::Q8, 64)).unwrap();
        let at_save = first.telemetry().deterministic_words();
        drop(first); // the kill

        let mut resumed = engine(2, mode);
        resumed.restore_state(ckpt::load(&dir).unwrap()).unwrap();
        assert_eq!(
            resumed.telemetry().deterministic_words(),
            at_save,
            "{mode:?}: restore must seed counters from the snapshot"
        );
        run(&mut resumed, 8);

        assert_eq!(
            resumed.telemetry().deterministic_words(),
            continuous.telemetry().deterministic_words(),
            "{mode:?}: resumed deterministic plane != continuous"
        );
        assert_eq!(
            det_plane(&resumed.telemetry().manifest_json()),
            det_plane(&continuous.telemetry().manifest_json()),
            "{mode:?}"
        );
        // Monotone continuation: nothing reset to zero and restarted.
        for (c, &before) in Counter::ALL.iter().zip(&at_save).take(DET_COUNTERS) {
            assert!(
                resumed.telemetry().get(*c) >= before,
                "{mode:?}: counter {} went backwards across resume",
                c.name()
            );
        }
        assert!(
            resumed.telemetry().get(Counter::WireBytes)
                > at_save[Counter::WireBytes as usize],
            "{mode:?}: wire bytes did not advance after resume"
        );
        std::fs::remove_dir_all(&dir).ok();
    }
}

/// Satellite: the three wire-byte surfaces — `Engine::wire_stats()`, the
/// sum of per-round `RoundReport.wire_bytes`, and the captured
/// `TrainState.wire_bytes` — agree after a multi-round run. All three
/// are reads of the one registry counter; a second `+=` site anywhere
/// would break this.
#[test]
fn wire_byte_surfaces_agree() {
    for mode in [CompressMode::None, CompressMode::Split] {
        let mut e = engine(2, mode);
        run(&mut e, 11); // 3 rounds at T=4, last one partial
        let ws = e.wire_stats();
        assert!(ws.bytes > 0);
        assert_eq!(ws.bytes, e.telemetry().get(Counter::WireBytes), "{mode:?}");
        assert_eq!(ws.messages, e.telemetry().get(Counter::WireMessages), "{mode:?}");
        let report_sum: u64 = e.reports().iter().map(|r| r.wire_bytes).sum();
        assert_eq!(report_sum, ws.bytes, "{mode:?}: round reports don't partition the total");
        let dense_sum: u64 = e.reports().iter().map(|r| r.wire_dense_bytes).sum();
        assert_eq!(dense_sum, ws.dense_bytes, "{mode:?}");
        let micro_sum: u64 = e.reports().iter().map(|r| r.micro_batches).sum();
        assert_eq!(micro_sum, e.telemetry().get(Counter::MicroBatches), "{mode:?}");
        // Split-layout messages partition their bytes into lane groups;
        // dense messages have no groups and meter zero there.
        if mode == CompressMode::Split {
            assert_eq!(ws.full_bytes + ws.free_bytes, ws.bytes, "{mode:?}");
        } else {
            assert_eq!(ws.full_bytes + ws.free_bytes, 0, "{mode:?}");
        }
        let st = e.capture_state().unwrap();
        assert_eq!(st.wire_bytes, ws.bytes, "{mode:?}: captured state disagrees");
        assert_eq!(st.wire_dense_bytes, ws.dense_bytes, "{mode:?}");
    }
}

/// The flight recorder observes the per-step phases and the exported run
/// directory is complete and parseable (what `frugal trace` consumes).
#[test]
fn spans_record_and_run_dir_exports() {
    let mut e = engine(2, CompressMode::Split);
    e.telemetry_mut().recorder.configure(64, true);
    run(&mut e, 6);
    // Threaded path: reduce covers the whole collect; decode and the
    // step kernel are timed on the training thread.
    for phase in [Phase::Reduce, Phase::Decode, Phase::StepKernel] {
        let s = e.telemetry().recorder.summary(phase);
        assert_eq!(s.count, 6, "{}", phase.name());
        assert!(s.p50_ns <= s.p99_ns && s.p99_ns <= s.max_ns.max(1), "{}", phase.name());
    }
    let dir = tmpdir("rundir");
    e.telemetry().write_run_dir(&dir).unwrap();
    for file in ["counters.json", "phases.jsonl", "spans.jsonl"] {
        let text = std::fs::read_to_string(dir.join(file)).unwrap();
        for chunk in text.lines().filter(|l| !l.trim().is_empty()) {
            Json::parse(chunk).unwrap_or_else(|e| panic!("{file}: {e}"));
        }
    }
    let manifest = std::fs::read_to_string(dir.join("counters.json")).unwrap();
    assert_eq!(manifest, e.telemetry().manifest_json(), "export is the canonical manifest");
    std::fs::remove_dir_all(&dir).ok();
}

/// Disabling spans changes nothing about the deterministic plane (the
/// two telemetry planes are strictly separated).
#[test]
fn spans_toggle_does_not_touch_counters() {
    let mut with = engine(1, CompressMode::Split);
    with.telemetry_mut().recorder.configure(256, true);
    let mut without = engine(1, CompressMode::Split);
    without.telemetry_mut().recorder.set_enabled(false);
    run(&mut with, 8);
    run(&mut without, 8);
    assert_eq!(
        with.telemetry().deterministic_words(),
        without.telemetry().deterministic_words()
    );
    assert_eq!(without.telemetry().recorder.summary(Phase::Reduce).count, 0);
    assert!(with.telemetry().recorder.summary(Phase::Reduce).count > 0);
}
