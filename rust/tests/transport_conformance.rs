//! Transport conformance suite (ISSUE 7).
//!
//! The contract under test: the wire is not allowed to change the math.
//! Whatever carries the frames — in-process channels, Unix-domain
//! sockets, TCP — a run is the same run:
//!
//! - length-prefixed frames roundtrip canonically over *real* streams
//!   (both socket flavors), not just through the in-memory codec;
//! - the loss trace and the deterministic telemetry plane are bitwise
//!   identical between the in-memory and socket backends at workers
//!   1/2/4 for compress none and split;
//! - arrival order is irrelevant: workers delayed by different amounts
//!   scramble slot arrival, and nothing changes;
//! - a worker dying mid-round surfaces as a targeted `WorkerLost` error
//!   (not a generic disconnect), naming the round;
//! - a worker leaving at a round boundary re-shards the fleet live
//!   (PR 5's elastic re-provisioning) without perturbing the trace —
//!   gradient math is worker-count independent.
//!
//! Socket workers here are protocol-faithful threads
//! ([`spawn_ref_workers`]) speaking the same frames as the `frugal
//! worker` subcommand, so the suite runs without child binaries.

use std::time::Duration;

use frugal::coordinator::subspace::{MaskBuilder, SubspacePolicy};
use frugal::coordinator::LrSchedule;
use frugal::engine::transport::{default_addr, worker_connect_retry, FrameIo, Listener};
use frugal::engine::{
    spawn_ref_workers, CompressCfg, CompressMode, EncodedGrad, Engine, EngineCfg, FaultCfg,
    Frame, GradSource, ParallelCfg, RefLm, RefLmCfg, Sources, TransportCfg, TransportKind,
    WorkerOpts,
};
use frugal::telemetry::Counter;
use frugal::optim::adamw::AdamCfg;
use frugal::optim::frugal::BlockPolicy;

const SEED: u64 = 11;
const T: u64 = 4;
const GRAD_ACCUM: usize = 4;

type WorkerHandles = Vec<std::thread::JoinHandle<frugal::Result<()>>>;

/// Stateless batch filler: a pure function of the global micro-batch
/// index, so remote workers and in-memory sources draw identical data.
fn batch_fn(micro: u64, buf: &mut Vec<i32>) {
    let cfg = RefLmCfg::default();
    let mut rng = frugal::util::Prng::seed_from_u64(0x7A95 ^ micro.wrapping_mul(0x9E37));
    buf.clear();
    buf.extend((0..cfg.batch * cfg.seq_len).map(|_| rng.range(0, cfg.vocab) as i32));
}

fn engine(workers: usize, mode: CompressMode, transport: TransportCfg) -> Engine {
    engine_with_fault(workers, mode, transport, FaultCfg::default())
}

fn engine_with_fault(
    workers: usize,
    mode: CompressMode,
    transport: TransportCfg,
    fault: FaultCfg,
) -> Engine {
    let m = RefLm::new(RefLmCfg::default());
    // Socket runs keep a single local source (evaluation only); the
    // in-memory transport needs one per worker.
    let n_local = if transport.kind == TransportKind::Memory { workers } else { 1 };
    let sources = Sources::Threaded(
        (0..n_local).map(|_| Box::new(m.clone()) as Box<dyn GradSource + Send>).collect(),
    );
    let mask_builder = MaskBuilder::new(
        m.layout().clone(),
        0.25,
        SubspacePolicy::Blockwise(BlockPolicy::Random),
        SEED,
    );
    let cfg = EngineCfg {
        parallel: ParallelCfg {
            workers,
            grad_accum: GRAD_ACCUM,
            compress: CompressCfg { mode, block: 64 },
            fault,
            ..Default::default()
        },
        schedule: LrSchedule::ConstantWarmup { warmup: 2 },
        peak_lr: 1e-3,
        lr_free_mult: 1.0,
        update_freq: T,
        adam: AdamCfg::default(),
        clip: None,
    };
    Engine::builder()
        .mask_builder(mask_builder)
        .cfg(cfg)
        .sources(sources)
        .init_flat(m.init_flat(SEED))
        .transport(transport)
        .build()
        .unwrap()
}

/// Spawn `opts.len()` worker threads against a fresh UDS address and an
/// engine targeting `workers` of them. Workers start first and
/// retry-connect; the engine's build blocks until the fleet joined.
fn socket_engine(
    workers: usize,
    mode: CompressMode,
    opts: Vec<WorkerOpts>,
) -> (Engine, WorkerHandles) {
    socket_engine_with_fault(workers, mode, opts, FaultCfg::default())
}

fn socket_engine_with_fault(
    workers: usize,
    mode: CompressMode,
    opts: Vec<WorkerOpts>,
    fault: FaultCfg,
) -> (Engine, WorkerHandles) {
    let addr = default_addr(TransportKind::Uds);
    let handles = spawn_ref_workers(TransportKind::Uds, addr.clone(), opts.len(), batch_fn, opts);
    let tcfg = TransportCfg {
        kind: TransportKind::Uds,
        addr: Some(addr),
        spawn: false,
        ..Default::default()
    };
    (engine_with_fault(workers, mode, tcfg, fault), handles)
}

fn trace(e: &mut Engine, steps: u64) -> Vec<u32> {
    (0..steps).map(|_| e.step(&batch_fn).unwrap().to_bits()).collect()
}

/// Join worker threads, requiring a clean protocol exit.
fn finish(handles: WorkerHandles) {
    for h in handles {
        h.join().expect("worker thread panicked").expect("worker errored");
    }
}

/// Frames survive real sockets — UDS and TCP — byte-for-byte, in both
/// directions, and a peer shutdown reads as a clean end-of-stream.
#[test]
fn frames_roundtrip_over_real_streams() {
    for kind in [TransportKind::Uds, TransportKind::Tcp] {
        let (listener, addr) = Listener::bind(kind, &default_addr(kind)).unwrap();
        let welcome = Frame::Welcome { worker: 1, config: "steps = 1\n".into() };
        let expected = welcome.clone();
        let client = std::thread::spawn(move || {
            let stream = worker_connect_retry(kind, &addr, Duration::from_secs(5)).unwrap();
            let mut io = FrameIo::new(stream);
            io.send(&Frame::Hello).unwrap();
            io.send(&Frame::Micro {
                worker: 1,
                attempt: 0,
                slot: 2,
                n_tok: 64,
                loss: 0.5,
                sig_free: 7,
                sig_full: 11,
                grad: EncodedGrad::Dense(vec![1.0, -2.5, f32::MIN_POSITIVE]),
            })
            .unwrap();
            assert_eq!(io.recv().unwrap().unwrap(), expected);
            io.send(&Frame::Shutdown).unwrap();
            // Close without another frame: the server must see a clean
            // end-of-stream, not an error.
        });
        let mut io = FrameIo::new(listener.accept().unwrap());
        assert_eq!(io.recv().unwrap().unwrap(), Frame::Hello);
        match io.recv().unwrap().unwrap() {
            Frame::Micro {
                worker: 1, slot: 2, n_tok: 64, loss, sig_free: 7, sig_full: 11, grad, ..
            } => {
                assert_eq!(loss.to_bits(), 0.5f32.to_bits(), "{kind}");
                assert_eq!(grad, EncodedGrad::Dense(vec![1.0, -2.5, f32::MIN_POSITIVE]));
            }
            other => panic!("{kind}: unexpected frame {other:?}"),
        }
        io.send(&welcome).unwrap();
        assert_eq!(io.recv().unwrap().unwrap(), Frame::Shutdown);
        client.join().unwrap();
        assert!(io.recv().unwrap().is_none(), "{kind}: peer close must read as EOF");
    }
}

/// Acceptance criterion: the socket backend is bitwise-indistinguishable
/// from the in-memory one — loss trace AND the deterministic telemetry
/// plane — at every worker count and codec.
#[test]
fn socket_run_is_bitwise_identical_to_in_memory() {
    for mode in [
        CompressMode::None,
        CompressMode::Split,
        CompressMode::TopK { k_permille: 10 },
        CompressMode::Q4,
        CompressMode::Adaptive { budget_permille: 20 },
    ] {
        for workers in [1usize, 2, 4] {
            let mut mem = engine(workers, mode, TransportCfg::default());
            let mem_trace = trace(&mut mem, 10);

            let (mut sock, handles) =
                socket_engine(workers, mode, vec![WorkerOpts::default(); workers]);
            let sock_trace = trace(&mut sock, 10);

            assert_eq!(
                mem_trace, sock_trace,
                "{mode:?} workers={workers}: socket loss trace diverged from in-memory"
            );
            assert_eq!(
                mem.telemetry().deterministic_words(),
                sock.telemetry().deterministic_words(),
                "{mode:?} workers={workers}: deterministic plane diverged across transports"
            );
            // The transport plane is where the backends MAY differ:
            // sockets serialize frames, in-memory never does.
            let sock_frames = sock.telemetry().get(frugal::telemetry::Counter::TransportFrames);
            let mem_frames = mem.telemetry().get(frugal::telemetry::Counter::TransportFrames);
            assert!(sock_frames > 0, "{mode:?} workers={workers}: socket metered no frames");
            assert_eq!(mem_frames, 0, "in-memory runs must not meter transport frames");
            drop(sock);
            finish(handles);
        }
    }
}

/// Arrival order is not part of the math: workers delayed by different
/// amounts deliver their slots interleaved arbitrarily, and the trace
/// still matches the undelayed in-memory run (reduce order is keyed by
/// micro-batch index, never by arrival).
#[test]
fn scrambled_arrival_order_does_not_change_the_trace() {
    let mut mem = engine(4, CompressMode::Split, TransportCfg::default());
    let mem_trace = trace(&mut mem, 8);

    let opts: Vec<WorkerOpts> = [11u64, 0, 7, 3]
        .iter()
        .map(|&ms| WorkerOpts { slot_delay_ms: ms, ..Default::default() })
        .collect();
    let (mut sock, handles) = socket_engine(4, CompressMode::Split, opts);
    let sock_trace = trace(&mut sock, 8);

    assert_eq!(mem_trace, sock_trace, "arrival order leaked into the reduction");
    assert_eq!(
        mem.telemetry().deterministic_words(),
        sock.telemetry().deterministic_words(),
        "deterministic plane is arrival-order dependent"
    );
    drop(sock);
    finish(handles);
}

/// A worker dying mid-round surfaces as the targeted `WorkerLost` error
/// naming the round — not as a generic disconnect/shutdown (the old
/// collector conflated the two).
#[test]
fn worker_death_mid_round_surfaces_worker_lost() {
    let mut opts = vec![WorkerOpts::default(); 2];
    // 1-based global step 6 = 0-based step 5: the second step of round
    // 2 at T=4, safely mid-round.
    opts[1].fault_step = Some(6);
    let (mut e, handles) = socket_engine(2, CompressMode::Split, opts);
    for _ in 0..5 {
        e.step(&batch_fn).unwrap();
    }
    let err = e.step(&batch_fn).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("lost in round 2"), "untargeted death error: {msg}");
    assert!(msg.contains("micro-batches delivered"), "missing delivery context: {msg}");
    drop(e);
    // The dying worker exits by protocol design; the survivor may be
    // cut off mid-send when the coordinator aborts — only require that
    // no thread panicked.
    for h in handles {
        let _ = h.join().expect("worker thread panicked");
    }
}

/// A worker announcing `Leave` keeps serving until the round boundary,
/// where the fleet re-shards live (elastic re-provisioning) — the
/// config reflects the new count and the trace never flinches, because
/// the math is worker-count independent.
#[test]
fn leave_at_round_boundary_resharding_preserves_the_trace() {
    let mut mem = engine(3, CompressMode::Split, TransportCfg::default());
    let mem_trace = trace(&mut mem, 12);

    let mut opts = vec![WorkerOpts::default(); 3];
    opts[2].leave_after_steps = Some(4); // departs at the first T=4 boundary
    let (mut sock, handles) = socket_engine(3, CompressMode::Split, opts);
    assert_eq!(sock.cfg().parallel.workers, 3);
    let sock_trace = trace(&mut sock, 12);

    assert_eq!(mem_trace, sock_trace, "membership change perturbed the loss trace");
    assert_eq!(
        sock.cfg().parallel.workers,
        2,
        "boundary re-sharding did not shrink the fleet"
    );
    assert_eq!(
        mem.telemetry().deterministic_words(),
        sock.telemetry().deterministic_words(),
        "deterministic plane diverged across a membership change"
    );
    drop(sock);
    finish(handles);
}

/// Tentpole acceptance: a scripted mid-round crash with recovery armed
/// (`[parallel.fault] max_round_retries > 0`) rewinds to the round
/// boundary, evicts the dead worker, re-shards over the survivors, and
/// deterministically replays the round — the full loss trace AND the
/// deterministic telemetry plane are bitwise-identical to a continuous
/// run at the surviving worker count, and the process exits nothing.
#[test]
fn mid_round_crash_recovers_bitwise_to_continuous_survivor_run() {
    let mut cont = engine(2, CompressMode::Split, TransportCfg::default());
    let cont_trace = trace(&mut cont, 12);

    let mut opts = vec![WorkerOpts::default(); 3];
    // 1-based step 6 is the second step of round 2 at T = 4: mid-round.
    opts[1].fault_step = Some(6);
    // A survivor also stalls briefly before the crash — injected delay
    // must never perturb the math, only the wall clock.
    opts[0].stall = Some((3, 20));
    let fault = FaultCfg { max_round_retries: 2, ..Default::default() };
    let (mut sock, handles) = socket_engine_with_fault(3, CompressMode::Split, opts, fault);
    let sock_trace = trace(&mut sock, 12);

    assert_eq!(
        cont_trace, sock_trace,
        "recovered trace diverged from the continuous 2-worker run"
    );
    assert_eq!(
        cont.telemetry().deterministic_words(),
        sock.telemetry().deterministic_words(),
        "deterministic plane diverged across a mid-round recovery"
    );
    assert_eq!(sock.cfg().parallel.workers, 2, "eviction did not shrink the fleet");
    assert_eq!(sock.telemetry().get(Counter::RoundsRetried), 1, "exactly one retry expected");
    assert_eq!(sock.telemetry().get(Counter::WorkersEvicted), 1, "exactly one eviction expected");
    drop(sock);
    // The crashed worker exits by script; survivors exit by protocol.
    for h in handles {
        let _ = h.join().expect("worker thread panicked");
    }
}

/// A corrupted wire frame (byte flipped after the CRC trailer was
/// computed) is rejected by the frame CRC-32, never reaches gradient
/// math, and routes through the same eviction + replay path a crash
/// does — the trace matches a continuous run without that worker.
#[test]
fn corrupt_frame_is_rejected_by_crc_and_routed_through_recovery() {
    let mut cont = engine(1, CompressMode::Split, TransportCfg::default());
    let cont_trace = trace(&mut cont, 12);

    let mut opts = vec![WorkerOpts::default(); 2];
    opts[0].corrupt_step = Some(6);
    let fault = FaultCfg { max_round_retries: 1, ..Default::default() };
    let (mut sock, handles) = socket_engine_with_fault(2, CompressMode::Split, opts, fault);
    let sock_trace = trace(&mut sock, 12);

    assert_eq!(
        cont_trace, sock_trace,
        "corruption leaked into the math (trace diverged from the 1-worker run)"
    );
    assert_eq!(
        cont.telemetry().deterministic_words(),
        sock.telemetry().deterministic_words(),
        "deterministic plane diverged across a CRC rejection"
    );
    assert!(
        sock.telemetry().get(Counter::FramesRejected) >= 1,
        "the CRC rejection was not counted"
    );
    assert_eq!(sock.telemetry().get(Counter::WorkersEvicted), 1);
    drop(sock);
    for h in handles {
        let _ = h.join().expect("worker thread panicked");
    }
}

/// Dropping below `min_workers` is not worth limping through: the run
/// halts at the round boundary with a targeted error (the orchestrator
/// layers the emergency snapshot on top of this message).
#[test]
fn below_min_workers_halts_with_a_targeted_error() {
    let mut opts = vec![WorkerOpts::default(); 2];
    opts[1].fault_step = Some(6);
    let fault = FaultCfg { max_round_retries: 2, min_workers: 2, ..Default::default() };
    let (mut e, handles) = socket_engine_with_fault(2, CompressMode::Split, opts, fault);
    for _ in 0..5 {
        e.step(&batch_fn).unwrap();
    }
    let err = e.step(&batch_fn).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("below min_workers"), "untargeted halt: {msg}");
    assert!(msg.contains("boundary"), "halt must name the rewind boundary: {msg}");
    drop(e);
    for h in handles {
        let _ = h.join().expect("worker thread panicked");
    }
}
