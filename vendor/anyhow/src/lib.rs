//! Minimal offline stand-in for the `anyhow` crate.
//!
//! This build runs on machines with no crates.io access, so the subset of
//! anyhow the workspace actually uses is vendored here: [`Error`],
//! [`Result`], and the `anyhow!` / `bail!` / `ensure!` macros. `Error`
//! captures the source chain as strings at conversion time; `{:#}`
//! formatting joins the chain with `": "` like the real crate.
//!
//! Intentionally NOT implemented (unused in this workspace): downcasting,
//! backtraces, `Context`/`with_context`.

use std::fmt;

/// Error type: a rendered message chain (outermost first).
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a plain message (what `anyhow!` expands to).
    pub fn msg(message: impl Into<String>) -> Error {
        Error { chain: vec![message.into()] }
    }

    /// The source chain, outermost message first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }
}

// Like real anyhow: any std error converts via `?`. `Error` itself does
// not implement `std::error::Error`, which keeps this blanket impl
// coherent with `impl From<T> for T`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(&self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain[0])?;
        for cause in &self.chain[1..] {
            write!(f, "\n\nCaused by:\n    {cause}")?;
        }
        Ok(())
    }
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(::std::string::ToString::to_string(&$err))
    };
}

/// Return early with an error built like `anyhow!`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return ::std::result::Result::Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn macro_forms() {
        let e = anyhow!("plain");
        assert_eq!(e.to_string(), "plain");
        let name = "x";
        let e = anyhow!("bad flag {name}");
        assert_eq!(e.to_string(), "bad flag x");
        let e = anyhow!("{} + {}", 1, 2);
        assert_eq!(e.to_string(), "1 + 2");
        let e = anyhow!(io_err());
        assert_eq!(e.to_string(), "missing file");
    }

    #[test]
    fn bail_and_ensure() {
        fn f(ok: bool) -> Result<u32> {
            ensure!(ok, "not ok");
            Ok(7)
        }
        fn g() -> Result<u32> {
            bail!("always fails: {}", 3);
        }
        assert_eq!(f(true).unwrap(), 7);
        assert_eq!(f(false).unwrap_err().to_string(), "not ok");
        assert_eq!(g().unwrap_err().to_string(), "always fails: 3");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<u64> {
            let n: u64 = "nope".parse()?;
            Ok(n)
        }
        assert!(f().is_err());
    }

    #[test]
    fn alternate_format_joins_chain() {
        let e = Error { chain: vec!["outer".into(), "inner".into()] };
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: inner");
        assert!(format!("{e:?}").contains("Caused by"));
    }
}
