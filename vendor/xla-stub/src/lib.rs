//! Offline stub of the `xla` (PJRT) crate API surface used by this repo.
//!
//! The real dependency wraps the PJRT C API and cannot be fetched or
//! linked on the offline build machines, so this stub provides the exact
//! types and method signatures `frugal::runtime` compiles against.
//! Every entry point that would touch PJRT returns [`Error`] at runtime
//! (`PjRtClient::cpu()` fails first, so nothing deeper is reachable).
//!
//! To run against real artifacts, point the `xla` dependency in the root
//! `Cargo.toml` at the actual crate instead of this path — the runtime
//! module needs no source changes.

use std::fmt;

/// Error raised by every stubbed PJRT entry point.
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "PJRT unavailable: {what} (this binary was built against the offline \
         xla stub; swap in the real xla crate to execute HLO artifacts)"
    ))
}

/// A host literal (dense array) — stubbed, holds no data.
pub struct Literal;

impl Literal {
    pub fn vec1<T: Copy>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(unavailable("Literal::reshape"))
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::to_tuple"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }

    pub fn get_first_element<T>(&self) -> Result<T> {
        Err(unavailable("Literal::get_first_element"))
    }
}

/// An on-device buffer handle.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// A compiled executable.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _inputs: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// The PJRT client. `cpu()` always fails in the stub, so a build against
/// this crate degrades to the pure-Rust engine paths.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

/// Parsed HLO module proto (from HLO text).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().err().expect("stub must fail");
        assert!(err.to_string().contains("PJRT unavailable"));
    }

    #[test]
    fn literal_constructors_exist() {
        let _ = Literal::vec1(&[1.0f32, 2.0]);
        let _ = Literal::vec1(&[1i32, 2]);
        assert!(Literal.reshape(&[2, 1]).is_err());
    }
}
